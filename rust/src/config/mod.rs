//! Configuration system: typed configs + a TOML-subset loader.
//!
//! Every binary (CLI, examples, benches) builds its run from these types;
//! `presets` holds the paper's configurations (the fabricated chip, the
//! ResNet-18 @ 224x224 workload, the three dataset difficulty presets).

pub mod toml;

use crate::classifier::ClassifierBackend;
use crate::hdc::Distance;
use crate::util::json::Json;

/// Feature-extractor / model geometry (must match `artifacts/manifest.json`
/// when the PJRT backend is used).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub image_size: usize,
    pub in_channels: usize,
    pub widths: Vec<usize>,
    pub blocks_per_stage: usize,
    /// final feature dimension F (= last width)
    pub feature_dim: usize,
    /// HDC dimension D
    pub d: usize,
    /// weight-clustering group size Ch_sub (paper: 64)
    pub ch_sub: usize,
    /// centroids per codebook N (paper: 16 -> 4-bit indices)
    pub n_centroids: usize,
    /// run the FE through the packed weight-clustered kernel (Fig. 4b) —
    /// the chip's cheap path. Quantizes every layer once at model build;
    /// requires `2 <= n_centroids <= 16`
    pub clustered: bool,
    /// cRP master seed (python/rust contract)
    pub master_seed: u64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            image_size: 32,
            in_channels: 3,
            widths: vec![16, 32, 64, 128],
            blocks_per_stage: 2,
            feature_dim: 128,
            d: 4096,
            ch_sub: 64,
            n_centroids: 16,
            clustered: false,
            master_seed: 0xF51_4D17,
        }
    }
}

impl ModelConfig {
    /// Load the geometry the artifacts were built with.
    pub fn from_manifest(man: &Json) -> anyhow::Result<Self> {
        let cfg = man.get("config").ok_or_else(|| anyhow::anyhow!("manifest missing config"))?;
        let req = |k: &str| {
            cfg.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("manifest config missing {k}"))
        };
        Ok(ModelConfig {
            image_size: req("image_size")? as usize,
            in_channels: req("in_channels")? as usize,
            widths: cfg
                .get("widths")
                .and_then(|v| v.as_usize_vec())
                .ok_or_else(|| anyhow::anyhow!("missing widths"))?,
            blocks_per_stage: 2,
            feature_dim: req("feature_dim")? as usize,
            d: req("d")? as usize,
            ch_sub: req("ch_sub")? as usize,
            n_centroids: req("n_centroids")? as usize,
            // clustered execution is a load-time choice (CLI/TOML), not an
            // artifact property — the manifest never sets it
            clustered: false,
            master_seed: req("master_seed")? as u64,
        })
    }

    /// Regenerate the stage geometry from a base width: `stages` widths
    /// doubling from `base_width`, with `feature_dim` following the widest
    /// stage (branch features are padded to it, never truncated). This is
    /// the synthetic-FE geometry knob behind `[model] base_width/stages`
    /// and the CLI `--base-width/--stages` flags.
    pub fn set_geometry(&mut self, base_width: usize, stages: usize) -> anyhow::Result<()> {
        anyhow::ensure!(base_width >= 1, "base_width must be >= 1");
        anyhow::ensure!((1..=8).contains(&stages), "stages must be in 1..=8");
        self.widths = (0..stages).map(|i| base_width << i).collect();
        self.feature_dim = *self.widths.last().unwrap();
        Ok(())
    }

    pub fn n_branches(&self) -> usize {
        self.widths.len()
    }

    /// Conv layers (stem + block convs + projection shortcuts) the
    /// standard block plan executes through the first `n_stages` stages —
    /// the accounting unit of the `fe_layers_executed` /
    /// `fe_layers_skipped` metrics. Mirrors the layer set
    /// `FeModel::synthetic` builds (a projection wherever a block changes
    /// channel count); the native backend reports its real plan instead,
    /// this formula covers the PJRT backend whose plan lives inside the
    /// artifact.
    pub fn conv_layers_through(&self, n_stages: usize) -> usize {
        let mut layers = 1; // stem
        let mut cin = self.widths.first().copied().unwrap_or(0); // stem output
        for &w in self.widths.iter().take(n_stages) {
            for _ in 0..self.blocks_per_stage {
                layers += 2;
                if cin != w {
                    layers += 1; // projection shortcut
                }
                cin = w;
            }
        }
        layers
    }
}

/// Batch-parallel execution policy for the native backend: how `fe_forward`
/// / `encode` batches are sharded across scoped worker threads
/// (DESIGN.md §Threading model). Output is bit-identical to serial for any
/// worker count, so this is purely a throughput knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// worker threads for batch sharding: 0 = auto (one per available
    /// core), 1 = serial (default), N = exactly N workers
    pub workers: usize,
    /// target minimum items per worker: shard count is capped at
    /// `batch / min_batch_per_worker`, so batches under twice this stay
    /// serial (thread spawn costs more than it saves)
    pub min_batch_per_worker: usize,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig { workers: 1, min_batch_per_worker: 2 }
    }
}

impl ParallelConfig {
    /// One worker per available core (the bench/CLI `--workers 0` setting).
    pub fn auto() -> Self {
        ParallelConfig { workers: 0, ..Default::default() }
    }

    /// `workers` with 0 resolved to the machine's available parallelism.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.workers
        }
    }

    /// Shard count for a batch of `n` items: capped at
    /// `n / min_batch_per_worker` (never below 1 shard), so sharding only
    /// kicks in once the batch can feed every worker about
    /// `min_batch_per_worker` items — the tail chunk may still be shorter.
    pub fn shards_for(&self, n: usize) -> usize {
        let by_batch = n / self.min_batch_per_worker.max(1);
        self.resolved_workers().min(by_batch).max(1)
    }
}

/// HDC classifier knobs ([hdc] TOML section / `--hv-bits`, `--metric`):
/// the class-memory precision sessions are created at and the distance
/// metric the packed datapath runs. Distinct from `ChipConfig::hv_bits`,
/// which parameterizes the chip simulator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HdcConfig {
    /// class-HV precision for new sessions, 1..=16 bits (paper capacity:
    /// 32 classes @ 16-bit, 128 @ 4-bit at D=4096)
    pub hv_bits: u32,
    /// distance metric (the chip's datapath is L1; hamming pairs with
    /// 1-bit class HVs for the popcount fast path)
    pub metric: Distance,
}

impl Default for HdcConfig {
    fn default() -> Self {
        // 4-bit is the paper's capacity sweet spot and what every example
        // historically created sessions at
        HdcConfig { hv_bits: 4, metric: Distance::L1 }
    }
}

/// Classifier-backend knobs (`[classifier]` TOML section / `--backend`,
/// `--ldc-d`): which FSL classifier new sessions run
/// ([`ClassifierBackend`]) and, for the LDC backend, the fold dimension
/// (DESIGN.md §Classifier backends). Orthogonal to [`HdcConfig`], whose
/// precision/metric knobs apply to *either* backend's prototype store.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassifierConfig {
    /// classifier new sessions are created with (`hdc` full-D class HVs,
    /// the paper's datapath; `ldc` folded low-D prototypes)
    pub backend: ClassifierBackend,
    /// LDC fold dimension, `0` = auto (`d / 8` clamped to `64..=512`);
    /// ignored by the HDC backend
    pub ldc_d: usize,
}

/// Few-shot workload: N-way k-shot episodes with q queries per class.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    pub n_way: usize,
    pub k_shot: usize,
    pub queries_per_class: usize,
    pub episodes: usize,
    pub dataset: String,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_way: 10,
            k_shot: 5,
            queries_per_class: 15,
            episodes: 20,
            dataset: "cifar100".into(),
            seed: 42,
        }
    }
}

/// Early-exit configuration (E_s, E_c) — Section V-A.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EeConfig {
    /// first CONV block (1-based) whose prediction participates
    pub e_s: usize,
    /// consecutive consistent predictions required to exit
    pub e_c: usize,
}

impl EeConfig {
    /// The paper's chosen operating point (Fig. 17): E_s=2, E_c=2.
    pub fn paper_default() -> Self {
        EeConfig { e_s: 2, e_c: 2 }
    }

    /// Validate a client-supplied configuration. Both fields are 1-based
    /// and must be >= 1; the coordinator rejects invalid configs with
    /// `Response::Error` instead of letting
    /// [`crate::coordinator::EarlyExitController::new`] panic its worker
    /// thread (the same bug class as out-of-range `hv_bits`).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.e_s >= 1, "ee.e_s must be >= 1 (1-based block index), got 0");
        anyhow::ensure!(self.e_c >= 1, "ee.e_c must be >= 1 consecutive agreements, got 0");
        Ok(())
    }

    /// Parse the `--ee E_S,E_C` flag the examples and CLI take (e.g.
    /// `"2,2"`), validated before it ever reaches a request.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let parts: Vec<&str> = s.split(',').map(str::trim).collect();
        anyhow::ensure!(parts.len() == 2, "--ee expects E_S,E_C (e.g. 2,2), got {s:?}");
        let e_s = parts[0].parse().map_err(|_| anyhow::anyhow!("bad E_S in --ee {s:?}"))?;
        let e_c = parts[1].parse().map_err(|_| anyhow::anyhow!("bad E_C in --ee {s:?}"))?;
        let ee = EeConfig { e_s, e_c };
        ee.validate()?;
        Ok(ee)
    }
}

/// Chip configuration (Fig. 7 / Fig. 13b).
#[derive(Clone, Debug, PartialEq)]
pub struct ChipConfig {
    pub freq_mhz: f64,
    pub voltage: f64,
    pub pe_rows: usize,
    pub pe_cols: usize,
    pub act_mem_kb: usize,
    pub idx_mem_kb: usize,
    pub cb_mem_kb: usize,
    pub class_mem_kb: usize,
    pub class_mem_banks: usize,
    /// HV precision for class memory, 1..=16 bits
    pub hv_bits: u32,
    /// off-chip DRAM bandwidth available for weight/index streaming (GB/s)
    pub dram_gbps: f64,
}

impl Default for ChipConfig {
    fn default() -> Self {
        // the fabricated FSL-HDnn chip at its fast corner
        ChipConfig {
            freq_mhz: 250.0,
            voltage: 1.2,
            pe_rows: 4,
            pe_cols: 16,
            act_mem_kb: 128,
            idx_mem_kb: 36,
            cb_mem_kb: 4,
            class_mem_kb: 256,
            class_mem_banks: 16,
            hv_bits: 16,
            // FPGA-bridged test-board DRAM (Fig. 13a): modest effective
            // bandwidth — calibrated so batching savings land in the
            // paper's 18-32% band (Fig. 16)
            dram_gbps: 0.22,
        }
    }
}

impl ChipConfig {
    /// Slow corner measured in Fig. 14(b): 100 MHz @ 0.9 V.
    pub fn slow_corner() -> Self {
        ChipConfig { freq_mhz: 100.0, voltage: 0.9, ..Default::default() }
    }

    /// Clock period in nanoseconds.
    pub fn period_ns(&self) -> f64 {
        1e3 / self.freq_mhz
    }
}

/// TCP-serving knobs (`[serving]` TOML section / `fsl-hdnn serve` flags):
/// where the gateway listens, when its admission control sheds load, and
/// how large a wire frame it accepts (DESIGN.md §Serving runtime).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServingConfig {
    /// bind address; port 0 picks an ephemeral port (the default binds
    /// loopback so a bare `serve` never exposes a public socket)
    pub addr: String,
    /// admission high-water mark: a request arriving while the serving
    /// queue depth (outstanding coordinator requests + queued pool tasks)
    /// *exceeds* this is refused with `Response::Busy { queue_depth }`
    pub high_water: usize,
    /// largest accepted frame payload in bytes; an oversized length
    /// prefix is a framing error and closes the connection
    pub max_frame_bytes: usize,
    /// per-request deadline in milliseconds; a coordinator call that does
    /// not answer in time is returned as a retryable deadline error
    /// (the worker still finishes the request — this bounds the caller's
    /// wait, not the device's work). `0` disables deadlines (default).
    pub deadline_ms: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            addr: "127.0.0.1:0".into(),
            // deep enough that a coordinator briefly behind on a batch
            // does not shed, shallow enough to bound queue latency
            high_water: 64,
            // a 224x224x3 image is ~1.7 MB as JSON; 64 MB covers large
            // query batches while still rejecting nonsense prefixes
            max_frame_bytes: 64 << 20,
            deadline_ms: 0,
        }
    }
}

/// Fault-injection knobs (`[faults]` TOML section / `--faults` flag /
/// `FSL_FAILPOINTS` env var): a fail-point spec armed at startup so
/// failure drills are reproducible from a config file (DESIGN.md §Fault
/// model). Empty (the default) arms nothing.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultConfig {
    /// fail-point spec, e.g. `"device.train=fail-once,gateway.write=fail-every-n:100"`
    /// (grammar in [`crate::util::failpoint::arm_spec`])
    pub points: String,
}

/// Top-level run configuration assembled by the CLI / examples.
#[derive(Clone, Debug, Default)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub workload: WorkloadConfig,
    pub chip: ChipConfig,
    pub hdc: HdcConfig,
    pub classifier: ClassifierConfig,
    pub ee: Option<EeConfig>,
    pub batched_training: bool,
    pub parallel: ParallelConfig,
    pub serving: ServingConfig,
    pub faults: FaultConfig,
}

impl RunConfig {
    /// Apply `key = value` pairs from a parsed TOML-subset document.
    /// The `[fe]` section carries the clustered-execution and
    /// synthetic-geometry knobs (`fe.ch_sub` / `fe.n_centroids` alias the
    /// `[model]` keys of the same name).
    pub fn apply_toml(&mut self, doc: &toml::Doc) -> anyhow::Result<()> {
        // geometry regeneration is deferred so base_width/stages compose
        // in any key order
        let mut base_width: Option<usize> = None;
        let mut stages: Option<usize> = None;
        for (section, key, val) in doc.entries() {
            let path =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            match path.as_str() {
                "model.d" => self.model.d = val.as_int()? as usize,
                "model.image_size" | "fe.image_size" => {
                    self.model.image_size = val.as_int()? as usize
                }
                "model.in_channels" => self.model.in_channels = val.as_int()? as usize,
                "model.blocks_per_stage" => self.model.blocks_per_stage = val.as_int()? as usize,
                "model.base_width" => base_width = Some(val.as_int()? as usize),
                "model.stages" => stages = Some(val.as_int()? as usize),
                "model.ch_sub" | "fe.ch_sub" => self.model.ch_sub = val.as_int()? as usize,
                "model.n_centroids" | "fe.n_centroids" => {
                    self.model.n_centroids = val.as_int()? as usize
                }
                "model.clustered" | "fe.clustered" => self.model.clustered = val.as_bool()?,
                "workload.n_way" => self.workload.n_way = val.as_int()? as usize,
                "workload.k_shot" => self.workload.k_shot = val.as_int()? as usize,
                "workload.queries_per_class" => {
                    self.workload.queries_per_class = val.as_int()? as usize
                }
                "workload.episodes" => self.workload.episodes = val.as_int()? as usize,
                "workload.dataset" => self.workload.dataset = val.as_str()?.to_string(),
                "workload.seed" => self.workload.seed = val.as_int()? as u64,
                "chip.freq_mhz" => self.chip.freq_mhz = val.as_float()?,
                "chip.voltage" => self.chip.voltage = val.as_float()?,
                "chip.hv_bits" => self.chip.hv_bits = val.as_int()? as u32,
                "hdc.hv_bits" => {
                    let bits = val.as_int()?;
                    anyhow::ensure!(
                        (1..=16).contains(&bits),
                        "hdc.hv_bits must be 1..=16, got {bits}"
                    );
                    self.hdc.hv_bits = bits as u32;
                }
                "hdc.metric" => self.hdc.metric = Distance::from_name(val.as_str()?)?,
                "classifier.backend" => {
                    self.classifier.backend = ClassifierBackend::from_name(val.as_str()?)?
                }
                "classifier.ldc_d" => {
                    let d = val.as_int()?;
                    anyhow::ensure!(
                        (0..=i64::from(u16::MAX)).contains(&d),
                        "classifier.ldc_d must be 0 (auto) or a small positive dim, got {d}"
                    );
                    self.classifier.ldc_d = d as usize;
                }
                "ee.e_s" => {
                    let e = self.ee.get_or_insert(EeConfig::paper_default());
                    e.e_s = val.as_int()? as usize;
                }
                "ee.e_c" => {
                    let e = self.ee.get_or_insert(EeConfig::paper_default());
                    e.e_c = val.as_int()? as usize;
                }
                "batched_training" => self.batched_training = val.as_bool()?,
                "parallel.workers" => self.parallel.workers = val.as_int()? as usize,
                "parallel.min_batch_per_worker" => {
                    self.parallel.min_batch_per_worker = val.as_int()? as usize
                }
                "serving.addr" => self.serving.addr = val.as_str()?.to_string(),
                "serving.high_water" => self.serving.high_water = val.as_int()? as usize,
                "serving.max_frame_bytes" => {
                    let bytes = val.as_int()?;
                    anyhow::ensure!(
                        (1..=u32::MAX as i64).contains(&bytes),
                        "serving.max_frame_bytes must fit the u32 length prefix, got {bytes}"
                    );
                    self.serving.max_frame_bytes = bytes as usize;
                }
                "serving.deadline_ms" => self.serving.deadline_ms = val.as_int()? as u64,
                "faults.points" => {
                    let spec = val.as_str()?.to_string();
                    // validate eagerly so a typo dies at config load, not
                    // silently at the first (never-firing) check
                    crate::util::failpoint::parse_spec(&spec)?;
                    self.faults.points = spec;
                }
                other => anyhow::bail!("unknown config key: {other}"),
            }
        }
        if base_width.is_some() || stages.is_some() {
            let bw = base_width.unwrap_or_else(|| self.model.widths.first().copied().unwrap_or(16));
            let ns = stages.unwrap_or(self.model.widths.len());
            self.model.set_geometry(bw, ns)?;
        }
        anyhow::ensure!(
            !self.model.clustered || (2..=16).contains(&self.model.n_centroids),
            "clustered FE needs 2 <= n_centroids <= 16, got {}",
            self.model.n_centroids
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_papers() {
        let c = ChipConfig::default();
        assert_eq!(c.pe_rows * c.pe_cols, 64);
        assert_eq!(c.act_mem_kb + c.idx_mem_kb + c.cb_mem_kb + c.class_mem_kb, 424);
        assert_eq!(ModelConfig::default().d, 4096);
    }

    #[test]
    fn apply_toml_full_roundtrip() {
        let doc = toml::Doc::parse(
            "batched_training = true\n\
             [model]\nd = 2048\n\
             [workload]\nn_way = 5\ndataset = \"flower102\"\n\
             [ee]\ne_s = 1\ne_c = 3\n\
             [chip]\nfreq_mhz = 100.0\nvoltage = 0.9\n",
        )
        .unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.model.d, 2048);
        assert_eq!(rc.workload.n_way, 5);
        assert_eq!(rc.workload.dataset, "flower102");
        assert_eq!(rc.ee, Some(EeConfig { e_s: 1, e_c: 3 }));
        assert!(rc.batched_training);
        assert_eq!(rc.chip.freq_mhz, 100.0);
    }

    #[test]
    fn apply_toml_fe_section_clustered_knobs() {
        let doc =
            toml::Doc::parse("[fe]\nclustered = true\nch_sub = 32\nn_centroids = 8\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert!(rc.model.clustered);
        assert_eq!((rc.model.ch_sub, rc.model.n_centroids), (32, 8));
        // [model] spellings stay accepted
        let doc = toml::Doc::parse("[model]\nclustered = false\nch_sub = 16\n").unwrap();
        rc.apply_toml(&doc).unwrap();
        assert!(!rc.model.clustered);
        assert_eq!(rc.model.ch_sub, 16);
    }

    #[test]
    fn apply_toml_rejects_unclusterable_n_centroids() {
        let doc = toml::Doc::parse("[fe]\nclustered = true\nn_centroids = 32\n").unwrap();
        let err = RunConfig::default().apply_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("n_centroids"), "{err}");
        // 32 centroids are fine as long as execution stays dense
        let doc = toml::Doc::parse("[fe]\nn_centroids = 32\n").unwrap();
        RunConfig::default().apply_toml(&doc).unwrap();
    }

    #[test]
    fn apply_toml_synthetic_geometry_knob() {
        let doc =
            toml::Doc::parse("[model]\nbase_width = 8\nstages = 3\nimage_size = 16\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.model.widths, vec![8, 16, 32]);
        assert_eq!(rc.model.feature_dim, 32, "feature_dim follows the widest stage");
        assert_eq!(rc.model.image_size, 16);
        // stages alone rescales the default width count
        let doc = toml::Doc::parse("[model]\nstages = 2\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.model.widths, vec![16, 32]);
        // out-of-range geometry errors
        let doc = toml::Doc::parse("[model]\nstages = 9\n").unwrap();
        assert!(RunConfig::default().apply_toml(&doc).is_err());
        let doc = toml::Doc::parse("[model]\nbase_width = 0\n").unwrap();
        assert!(RunConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn conv_layers_through_counts_the_standard_plan() {
        // default geometry: widths [16,32,64,128] x 2 blocks; stage 0 has
        // no projection (stem already outputs 16 channels), stages 1..3
        // project on their first block
        let m = ModelConfig::default();
        assert_eq!(m.conv_layers_through(0), 1, "stem only");
        assert_eq!(m.conv_layers_through(1), 5);
        assert_eq!(m.conv_layers_through(2), 10);
        assert_eq!(m.conv_layers_through(4), 20);
        // clamped past the last stage
        assert_eq!(m.conv_layers_through(99), 20);
    }

    #[test]
    fn ee_config_validation() {
        assert!(EeConfig::paper_default().validate().is_ok());
        assert!(EeConfig { e_s: 1, e_c: 1 }.validate().is_ok());
        let err = EeConfig { e_s: 0, e_c: 2 }.validate().unwrap_err().to_string();
        assert!(err.contains("e_s"), "{err}");
        let err = EeConfig { e_s: 2, e_c: 0 }.validate().unwrap_err().to_string();
        assert!(err.contains("e_c"), "{err}");
    }

    #[test]
    fn ee_config_parse_flag_syntax() {
        assert_eq!(EeConfig::parse("2,2").unwrap(), EeConfig::paper_default());
        assert_eq!(EeConfig::parse(" 1 , 3 ").unwrap(), EeConfig { e_s: 1, e_c: 3 });
        for bad in ["2", "2,2,2", "a,1", "0,2", "1,0", ""] {
            assert!(EeConfig::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn apply_toml_rejects_unknown() {
        let doc = toml::Doc::parse("[model]\nbogus = 1\n").unwrap();
        assert!(RunConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn apply_toml_hdc_section() {
        use crate::hdc::Distance;
        let doc = toml::Doc::parse("[hdc]\nhv_bits = 1\nmetric = \"hamming\"\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.hdc, HdcConfig { hv_bits: 1, metric: Distance::Hamming });
        // [chip] hv_bits stays the simulator knob, untouched
        assert_eq!(rc.chip.hv_bits, ChipConfig::default().hv_bits);
        // bad values fail with a clean error
        let doc = toml::Doc::parse("[hdc]\nhv_bits = 17\n").unwrap();
        let err = RunConfig::default().apply_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("1..=16"), "{err}");
        let doc = toml::Doc::parse("[hdc]\nmetric = \"euclid\"\n").unwrap();
        assert!(RunConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn apply_toml_classifier_section() {
        let doc = toml::Doc::parse("[classifier]\nbackend = \"ldc\"\nldc_d = 256\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(
            rc.classifier,
            ClassifierConfig { backend: ClassifierBackend::Ldc, ldc_d: 256 }
        );
        // default stays the paper's HDC with auto fold dim
        let d = ClassifierConfig::default();
        assert_eq!((d.backend, d.ldc_d), (ClassifierBackend::Hdc, 0));
        // unknown backend names fail with the parse error, not a panic
        let doc = toml::Doc::parse("[classifier]\nbackend = \"svm\"\n").unwrap();
        let err = RunConfig::default().apply_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("svm"), "{err}");
        // absurd fold dims are rejected at config time
        let doc = toml::Doc::parse("[classifier]\nldc_d = 100000\n").unwrap();
        assert!(RunConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn hdc_defaults_are_the_paper_sweet_spot() {
        use crate::hdc::Distance;
        let h = HdcConfig::default();
        assert_eq!((h.hv_bits, h.metric), (4, Distance::L1));
    }

    #[test]
    fn apply_toml_parallel_keys() {
        let doc =
            toml::Doc::parse("[parallel]\nworkers = 4\nmin_batch_per_worker = 3\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.parallel, ParallelConfig { workers: 4, min_batch_per_worker: 3 });
    }

    #[test]
    fn parallel_defaults_are_serial() {
        let p = ParallelConfig::default();
        assert_eq!(p.workers, 1);
        assert_eq!(p.resolved_workers(), 1);
        assert_eq!(p.shards_for(1000), 1);
    }

    #[test]
    fn shards_capped_by_min_batch_per_worker() {
        let p = ParallelConfig { workers: 8, min_batch_per_worker: 2 };
        assert_eq!(p.shards_for(0), 1, "empty batch still one (no-op) shard");
        assert_eq!(p.shards_for(1), 1, "single item stays serial");
        assert_eq!(p.shards_for(4), 2, "4 items / min 2 per worker = 2 shards");
        assert_eq!(p.shards_for(16), 8, "worker count is the ceiling");
        assert_eq!(p.shards_for(1000), 8);
        // min_batch_per_worker = 0 is treated as 1 (no div-by-zero)
        let p0 = ParallelConfig { workers: 3, min_batch_per_worker: 0 };
        assert_eq!(p0.shards_for(2), 2);
    }

    #[test]
    fn apply_toml_serving_keys() {
        let doc = toml::Doc::parse(
            "[serving]\naddr = \"0.0.0.0:7433\"\nhigh_water = 8\nmax_frame_bytes = 1048576\n",
        )
        .unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.serving.addr, "0.0.0.0:7433");
        assert_eq!(rc.serving.high_water, 8);
        assert_eq!(rc.serving.max_frame_bytes, 1 << 20);
        // a frame cap that cannot be length-prefixed in u32 is rejected
        let doc = toml::Doc::parse("[serving]\nmax_frame_bytes = 0\n").unwrap();
        assert!(RunConfig::default().apply_toml(&doc).is_err());
        let doc = toml::Doc::parse("[serving]\nmax_frame_bytes = 4294967296\n").unwrap();
        assert!(RunConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn apply_toml_faults_and_deadline_keys() {
        let doc = toml::Doc::parse(
            "[serving]\ndeadline_ms = 250\n\
             [faults]\npoints = \"device.query=latency-ms:1,gateway.write=fail-once\"\n",
        )
        .unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.serving.deadline_ms, 250);
        assert_eq!(rc.faults.points, "device.query=latency-ms:1,gateway.write=fail-once");
        // a bad spec dies at config load (validated eagerly, never armed)
        let doc = toml::Doc::parse("[faults]\npoints = \"device.query=warble\"\n").unwrap();
        assert!(RunConfig::default().apply_toml(&doc).is_err());
        // defaults: deadlines off, nothing armed
        assert_eq!(ServingConfig::default().deadline_ms, 0);
        assert_eq!(FaultConfig::default().points, "");
    }

    #[test]
    fn serving_defaults_bind_loopback() {
        let s = ServingConfig::default();
        assert!(s.addr.starts_with("127.0.0.1:"), "default must never expose a public socket");
        assert!(s.high_water >= 1);
        assert!(s.max_frame_bytes <= u32::MAX as usize);
    }

    #[test]
    fn auto_resolves_to_at_least_one_worker() {
        let p = ParallelConfig::auto();
        assert_eq!(p.workers, 0);
        assert!(p.resolved_workers() >= 1);
    }
}
