//! 16-bit Fibonacci LFSR — rust half of the python/rust bit-exactness
//! contract (see `python/compile/kernels/lfsr.py` for the spec and the
//! block-schedule rationale).
//!
//! Polynomial x^16 + x^15 + x^13 + x^4 + 1 (taps 16,15,13,4; maximal).

use crate::util::prng::GOLDEN;

pub const MASK16: u16 = 0xFFFF;

/// One LFSR step.
#[inline]
pub fn step(s: u16) -> u16 {
    let fb = ((s >> 15) ^ (s >> 14) ^ (s >> 12) ^ (s >> 3)) & 1;
    (s << 1) | fb
}

/// Sixteen steps — one fresh 16-bit word (one cyclic-block column advance).
#[inline]
pub fn step16(mut s: u16) -> u16 {
    for _ in 0..16 {
        s = step(s);
    }
    s
}

/// step16 is linear over GF(2) (the feedback is a pure XOR of state bits,
/// no constant term), so `step16(a ^ b) = step16(a) ^ step16(b)` and the
/// 16-step jump decomposes into two byte-indexed table lookups. This is
/// the cRP encoder's hottest scalar op — see EXPERIMENTS.md §Perf.
const fn build_step16_table(shift: u32) -> [u16; 256] {
    let mut t = [0u16; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut s = (i as u16) << shift;
        let mut n = 0;
        while n < 16 {
            let fb = ((s >> 15) ^ (s >> 14) ^ (s >> 12) ^ (s >> 3)) & 1;
            s = (s << 1) | fb;
            n += 1;
        }
        t[i] = s;
        i += 1;
    }
    t
}

static STEP16_LO: [u16; 256] = build_step16_table(0);
static STEP16_HI: [u16; 256] = build_step16_table(8);

/// Table-accelerated 16-step jump; bit-identical to [`step16`].
#[inline(always)]
pub fn step16_fast(s: u16) -> u16 {
    STEP16_LO[(s & 0xFF) as usize] ^ STEP16_HI[(s >> 8) as usize]
}

/// Initial states of the 16 LFSRs for row-block `i` of a D x F encoder.
/// Mirrors `lfsr.row_block_states`: chain splitmix64 from
/// `master_seed ^ (i+1)*GOLDEN`, low 16 bits, zero remapped to 0xACE1.
pub fn row_block_states(master_seed: u64, i: u64) -> [u16; 16] {
    let mut s = master_seed ^ (i.wrapping_add(1)).wrapping_mul(GOLDEN);
    let mut out = [0u16; 16];
    for v in out.iter_mut() {
        // python chains on the MIXED output: s = splitmix64(s)
        s = crate::util::prng::splitmix64_next(s);
        let w = (s & MASK16 as u64) as u16;
        *v = if w == 0 { 0xACE1 } else { w };
    }
    out
}

/// All row-block states for a D-dimensional encoder: (d/16) x 16.
pub fn all_row_states(master_seed: u64, d: usize) -> Vec<[u16; 16]> {
    assert_eq!(d % 16, 0, "D must be a multiple of 16");
    (0..d / 16).map(|i| row_block_states(master_seed, i as u64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maximal_period() {
        let s0 = 1u16;
        let mut s = step(s0);
        let mut n = 1u32;
        while s != s0 {
            s = step(s);
            n += 1;
            assert!(n <= 65535, "not maximal");
        }
        assert_eq!(n, 65535);
    }

    #[test]
    fn zero_lockup() {
        assert_eq!(step(0), 0);
    }

    #[test]
    fn step16_equals_16_steps() {
        let mut s = 0xBEEFu16;
        let quick = step16(s);
        for _ in 0..16 {
            s = step(s);
        }
        assert_eq!(quick, s);
    }

    /// Golden sequence from python: lfsr16_step chain starting at 0xACE1.
    /// (printed by `python -c "...lfsr.golden_vectors()..."` — the same
    /// values land in artifacts/goldens/goldens.json).
    #[test]
    fn python_step_golden() {
        let mut s = 0xACE1u16;
        let expect: [u16; 8] = [0x59c3, 0xb386, 0x670c, 0xce18, 0x9c31, 0x3862, 0x70c5, 0xe18a];
        for e in expect {
            s = step(s);
            assert_eq!(s, e, "LFSR diverges from python");
        }
    }

    #[test]
    fn step16_fast_bit_identical() {
        // exhaustive: the table jump must equal 16 sequential steps for
        // every possible state
        for s in 0..=u16::MAX {
            assert_eq!(step16_fast(s), step16(s), "state {s:#06x}");
        }
    }

    #[test]
    fn row_states_nonzero_deterministic() {
        let a = row_block_states(123, 5);
        let b = row_block_states(123, 5);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| v != 0));
        assert_ne!(row_block_states(123, 6), a);
    }
}
