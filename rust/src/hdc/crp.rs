//! Cyclic Random Projection encoder (Fig. 6b) — native hot path.
//!
//! Streams the D x F ±1 base matrix out of 16 LFSRs, 16x16 elements per
//! "cycle", with O(1) live state: memory is 16 u16 states + one 16x16
//! block, exactly the chip's O(B) property. Bit-compatible with the Pallas
//! kernel (`crp_encoder.py`): same seed derivation, same 16-steps-per-block
//! advance, same (row-band, column-block) schedule.

use super::lfsr;

/// Streaming cRP encoder for a fixed (D, master_seed).
#[derive(Clone, Debug)]
pub struct CrpEncoder {
    pub d: usize,
    pub master_seed: u64,
}

impl CrpEncoder {
    pub fn new(d: usize, master_seed: u64) -> Self {
        assert_eq!(d % 16, 0, "D must be a multiple of 16");
        CrpEncoder { d, master_seed }
    }

    /// Encode one feature vector into `out` (len D). `x.len()` must be a
    /// multiple of 16 (zero-pad shorter features — zero columns contribute
    /// nothing, see `test_crp_zero_padding_is_noop_on_prefix`).
    pub fn encode_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len() % 16, 0, "F must be a multiple of 16 (zero-pad)");
        assert_eq!(out.len(), self.d);
        let ncol = x.len() / 16;
        // Precompute, once per encode, 4 nibble subset-sum tables per
        // column block: sum over any bit subset of a 16-value segment
        // becomes 4 lookups + 3 adds, and the ±1 contraction uses
        //   sum_r = 2 * subset_sum(state_r) - total.
        // The tables depend only on the features, so all D/16 bands share
        // them; together with the table-jump LFSR the inner loop is pure
        // lookups (EXPERIMENTS.md §Perf).
        let mut tables: Vec<[[f32; 16]; 4]> = vec![[[0f32; 16]; 4]; ncol];
        let mut totals = vec![0f32; ncol];
        for (j, tj) in tables.iter_mut().enumerate() {
            let seg = &x[j * 16..(j + 1) * 16];
            for (g, t) in tj.iter_mut().enumerate() {
                let base = &seg[g * 4..g * 4 + 4];
                for m in 1..16usize {
                    let low = m & m.wrapping_neg();
                    t[m] = t[m & (m - 1)] + base[low.trailing_zeros() as usize];
                }
            }
            totals[j] = tj[0][15] + tj[1][15] + tj[2][15] + tj[3][15];
        }
        for (i, band) in out.chunks_exact_mut(16).enumerate() {
            let mut states = lfsr::row_block_states(self.master_seed, i as u64);
            let mut acc = [0f32; 16];
            for (tj, &total) in tables.iter().zip(&totals) {
                for r in 0..16 {
                    let st = lfsr::step16_fast(states[r]);
                    states[r] = st;
                    let s = st as usize;
                    let set = tj[0][s & 15]
                        + tj[1][(s >> 4) & 15]
                        + tj[2][(s >> 8) & 15]
                        + tj[3][(s >> 12) & 15];
                    acc[r] += 2.0 * set - total;
                }
            }
            band.copy_from_slice(&acc);
        }
    }

    /// Encode and allocate.
    pub fn encode(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.d];
        self.encode_into(x, &mut out);
        out
    }

    /// Encode a feature of arbitrary length by zero-padding to 16.
    pub fn encode_padded(&self, x: &[f32]) -> Vec<f32> {
        let f = x.len().div_ceil(16) * 16;
        if f == x.len() {
            return self.encode(x);
        }
        let mut xp = vec![0f32; f];
        xp[..x.len()].copy_from_slice(x);
        self.encode(&xp)
    }

    /// Batched [`CrpEncoder::encode_padded`], sharded across scoped worker
    /// threads (`shards <= 1` stays serial). The encoder is stateless per
    /// call — LFSR states are derived fresh for every row band — so shards
    /// share `&self` and the output is bit-identical to the serial loop
    /// for any shard count (DESIGN.md §Threading model).
    pub fn encode_batch(&self, feats: &[Vec<f32>], shards: usize) -> Vec<Vec<f32>> {
        crate::util::parallel::shard_map(feats, shards, |f| Ok(self.encode_padded(f)))
            .expect("encode_padded is infallible")
    }

    /// Number of LFSR "cycles" (16x16 blocks) one encode consumes — the
    /// chip-cycle analogue used by the simulator: D*F/256.
    pub fn blocks(&self, f: usize) -> u64 {
        (self.d as u64 * f as u64) / 256
    }

    /// Materialize the dense base matrix (tests only; production never does).
    #[doc(hidden)]
    pub fn dense_base(&self, f: usize) -> Vec<Vec<f32>> {
        assert_eq!(f % 16, 0);
        let mut rows = vec![vec![0f32; f]; self.d];
        for i in 0..self.d / 16 {
            let mut states = lfsr::row_block_states(self.master_seed, i as u64);
            for j in 0..f / 16 {
                for s in states.iter_mut() {
                    *s = lfsr::step16(*s);
                }
                for r in 0..16 {
                    for c in 0..16 {
                        let sign = if (states[r] >> c) & 1 == 1 { 1.0 } else { -1.0 };
                        rows[i * 16 + r][j * 16 + c] = sign;
                    }
                }
            }
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn encode_matches_dense_matmul() {
        let enc = CrpEncoder::new(64, 99);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..32).map(|_| rng.gauss_f32()).collect();
        let dense = enc.dense_base(32);
        let h = enc.encode(&x);
        for (i, row) in dense.iter().enumerate() {
            let want: f32 = row.iter().zip(&x).map(|(a, b)| a * b).sum();
            assert!((h[i] - want).abs() < 1e-4, "row {i}: {} vs {want}", h[i]);
        }
    }

    #[test]
    fn linearity() {
        let enc = CrpEncoder::new(96, 5);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..48).map(|_| rng.gauss_f32()).collect();
        let y: Vec<f32> = (0..48).map(|_| rng.gauss_f32()).collect();
        let z: Vec<f32> = x.iter().zip(&y).map(|(a, b)| 2.0 * a + b).collect();
        let hx = enc.encode(&x);
        let hy = enc.encode(&y);
        let hz = enc.encode(&z);
        for i in 0..96 {
            assert!((hz[i] - (2.0 * hx[i] + hy[i])).abs() < 1e-3);
        }
    }

    #[test]
    fn zero_padding_noop() {
        let enc = CrpEncoder::new(64, 7);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..32).map(|_| rng.gauss_f32()).collect();
        let mut xp = x.clone();
        xp.extend([0.0; 32]);
        assert_eq!(enc.encode(&x), enc.encode(&xp));
    }

    #[test]
    fn encode_padded_pads() {
        let enc = CrpEncoder::new(32, 7);
        let x = vec![1.0f32; 20]; // not a multiple of 16
        let h = enc.encode_padded(&x);
        assert_eq!(h.len(), 32);
    }

    #[test]
    fn distance_preserved_in_expectation() {
        // Johnson-Lindenstrauss sanity: ||h(x)||^2 / D ~ ||x||^2
        let enc = CrpEncoder::new(4096, 11);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..64).map(|_| rng.gauss_f32()).collect();
        let h = enc.encode(&x);
        let nx: f32 = x.iter().map(|v| v * v).sum();
        let nh: f32 = h.iter().map(|v| v * v).sum::<f32>() / 4096.0;
        assert!((nh / nx - 1.0).abs() < 0.2, "JL ratio {}", nh / nx);
    }

    #[test]
    fn blocks_count() {
        let enc = CrpEncoder::new(4096, 0);
        assert_eq!(enc.blocks(512), 8192);
    }

    #[test]
    fn encode_batch_bit_identical_to_serial() {
        let enc = CrpEncoder::new(128, 13);
        let mut rng = Rng::new(5);
        let feats: Vec<Vec<f32>> =
            (0..9).map(|_| (0..48).map(|_| rng.gauss_f32()).collect()).collect();
        let serial: Vec<Vec<f32>> = feats.iter().map(|f| enc.encode_padded(f)).collect();
        for shards in [1, 2, 4, 9, 32] {
            assert_eq!(enc.encode_batch(&feats, shards), serial, "shards={shards}");
        }
    }
}
