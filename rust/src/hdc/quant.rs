//! Class-HV quantization: the chip stores class HVs at 1..16-bit integer
//! precision in the 256 KB class memory (Section IV-B4). Lower precision
//! fits more classes (32 @ 16-bit, 128 @ 4-bit at D=4096) and costs less
//! energy per distance computation (Fig. 14a).

/// Quantize an f32 HV to `bits`-bit signed integer codes (symmetric,
/// per-vector scale). The dequantized representation is `code * scale`
/// element-wise; this is what [`crate::hdc::packed::PackedClassHvs`]
/// stores and what [`quantize`] materializes.
pub fn quantize_codes(hv: &[f32], bits: u32) -> (Vec<i32>, f32) {
    assert!((1..=16).contains(&bits), "HV precision is 1..=16 bits");
    if bits == 1 {
        // sign binarization; scale keeps magnitudes comparable
        let mean_abs = hv.iter().map(|v| v.abs()).sum::<f32>() / hv.len().max(1) as f32;
        let codes: Vec<i32> = hv.iter().map(|&v| if v >= 0.0 { 1 } else { -1 }).collect();
        return (codes, mean_abs);
    }
    let max_abs = hv.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if max_abs == 0.0 {
        return (vec![0; hv.len()], 1.0);
    }
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let scale = max_abs / qmax;
    let codes: Vec<i32> = hv
        .iter()
        .map(|&v| (v / scale).round().clamp(-qmax, qmax) as i32)
        .collect();
    (codes, scale)
}

/// Quantize an f32 HV to `bits`-bit signed integers, returning the
/// dequantized f32 representation the distance datapath would see plus the
/// scale. `code as f32 * scale` reproduces the historical direct
/// computation bit-for-bit (integral codes ≤ 2^15 are exact in f32).
pub fn quantize(hv: &[f32], bits: u32) -> (Vec<f32>, f32) {
    let (codes, scale) = quantize_codes(hv, bits);
    (codes.iter().map(|&c| c as f32 * scale).collect(), scale)
}

/// Storage bits for one class HV at dimension `d`.
pub fn storage_bits(d: usize, bits: u32) -> u64 {
    d as u64 * bits as u64
}

/// How many class HVs fit in a class memory of `mem_kb` KB (paper: 256 KB
/// holds 32 classes at 16-bit / 128 at 4-bit, D=4096).
pub fn classes_capacity(mem_kb: usize, d: usize, bits: u32) -> usize {
    let mem_bits = mem_kb as u64 * 1024 * 8;
    (mem_bits / storage_bits(d, bits)) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn paper_capacity_numbers() {
        assert_eq!(classes_capacity(256, 4096, 16), 32);
        assert_eq!(classes_capacity(256, 4096, 4), 128);
    }

    #[test]
    fn quantize_is_idempotent_in_error() {
        let mut rng = Rng::new(1);
        let hv: Vec<f32> = (0..256).map(|_| rng.gauss_f32()).collect();
        let (q8, _) = quantize(&hv, 8);
        let (q8b, _) = quantize(&q8, 8);
        // re-quantizing changes the scale slightly but values stay close
        for (a, b) in q8.iter().zip(&q8b) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Rng::new(2);
        let hv: Vec<f32> = (0..1024).map(|_| rng.gauss_f32() * 3.0).collect();
        let mut prev = f64::INFINITY;
        for bits in [2u32, 4, 8, 12, 16] {
            let (q, _) = quantize(&hv, bits);
            let mse: f64 = hv
                .iter()
                .zip(&q)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>()
                / hv.len() as f64;
            assert!(mse <= prev + 1e-12, "mse should fall with precision");
            prev = mse;
        }
        assert!(prev < 1e-6);
    }

    #[test]
    fn one_bit_is_sign() {
        let hv = [3.0f32, -0.5, 0.0, -2.0];
        let (q, scale) = quantize(&hv, 1);
        assert!(scale > 0.0);
        assert!(q[0] > 0.0 && q[1] < 0.0 && q[2] >= 0.0 && q[3] < 0.0);
        assert_eq!(q[0], -q[1].signum() * q[0].abs());
    }

    #[test]
    fn zero_vector_safe() {
        let (q, _) = quantize(&[0.0; 8], 8);
        assert!(q.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn codes_dequantize_to_quantize_output() {
        // the integer-code view and the f32 view are the same quantizer:
        // code * scale must reproduce quantize() exactly, at every precision
        let mut rng = Rng::new(3);
        let hv: Vec<f32> = (0..333).map(|_| 5.0 * rng.gauss_f32()).collect();
        for bits in [1u32, 2, 4, 8, 12, 16] {
            let (q, s) = quantize(&hv, bits);
            let (codes, cs) = quantize_codes(&hv, bits);
            assert_eq!(s, cs, "bits={bits}");
            let qmax = if bits == 1 { 1 } else { (1i32 << (bits - 1)) - 1 };
            for (i, (&code, &want)) in codes.iter().zip(&q).enumerate() {
                assert!(code.abs() <= qmax, "bits={bits} idx {i}: code {code} out of range");
                assert_eq!(code as f32 * cs, want, "bits={bits} idx {i}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_bad_bits() {
        quantize(&[1.0], 17);
    }
}
