//! Packed quantized class memory — the native HDC inference fast path.
//!
//! The chip's 256 KB class memory (Section IV-B4) stores class HVs at
//! 1..16-bit precision and its distance module accumulates in the integer
//! domain; the capacity *and* energy wins of low precision (Fig. 14a) come
//! from never widening back to f32. This module mirrors that datapath in
//! software, the same way `fe::conv::clustered_conv2d_packed` mirrors the
//! Fig. 4b conv: a packed kernel plus the readable dequantized-f32 path
//! ([`crate::hdc::HdcModel::distances_oracle`]) kept as the numerical
//! oracle.
//!
//! Storage, chosen by `hv_bits`:
//! * 1 bit — sign planes in `u64` words; every metric reduces to XOR +
//!   popcount (the LDC/ImageHD-style binary fast path).
//! * 2..=4 bits — signed nibbles, two codes per byte (the chip's 4-bit
//!   class-HV mode).
//! * 5..=8 / 9..=16 bits — `i8` / `i16` codes.
//!
//! Integer-domain accounting, per metric (the oracle contract each kernel
//! keeps with the dequantized-f32 reference — tested in this module and in
//! `prop_tests.rs`):
//! * **Hamming** — exact integer mismatch count; *equal* to the oracle.
//! * **Dot** — exact `i64` code-product accumulation, scaled once at the
//!   end; within f32-association tolerance of the oracle (which rounds
//!   each product to f32).
//! * **L1, 1-bit** — popcount algebra (`n_match·|s_q−s_c| +
//!   n_mismatch·(s_q+s_c)`); within accumulation-order tolerance.
//! * **L1, multi-bit** — per-vector scales make integer-exact L1
//!   impossible (the chip has one global precision domain; we keep scales
//!   for f32 interchangeability), so the kernel streams the narrow codes
//!   and dequantizes in-register with the *same* 4-lane accumulation as
//!   `distance::l1` — bit-identical to the oracle, at a quarter (i8) to
//!   half (i16) the memory traffic.
//! * **Cosine** — off the chip's datapath; evaluated over a materialized
//!   dequantized row (bit-identical to the oracle, not accelerated).
//!
//! Queries quantize **once** ([`PackedClassHvs::quantize_query`]) and every
//! class comparison then runs in the code domain — unlike the pre-packed
//! implementation, which dequantized the whole class memory to f32 on
//! every rebuild and compared against the raw f32 query.
//!
//! The kernels run through `util::simd` in explicit width (DESIGN.md §SIMD
//! datapath): 4-word popcount chunks on the 1-bit planes, 4-lane
//! byte-pair nibble streaming for the 2–4-bit L1/dot/hamming paths (no
//! per-element [`nibble_at`] in any inner loop), and `L1Sink`-generic
//! dequantize-in-register accumulation that keeps the multi-bit L1
//! bit-identity contract under both kernel lanes.
//! [`PackedClassHvs::distances`] dispatches on the immutable
//! process-wide lane; [`PackedClassHvs::distances_in_lane`] is the
//! lane-explicit entry point benches and prop tests use.

use super::distance::Distance;
use super::quant;
use crate::util::simd::{self, L1Sink, Lane};

/// A query HV quantized once to the class-memory precision.
#[derive(Clone, Debug)]
pub struct PackedQuery {
    pub d: usize,
    pub hv_bits: u32,
    pub scale: f32,
    /// integer codes (multi-bit precisions; empty at 1 bit)
    codes: Vec<i16>,
    /// dequantized view `code * scale` — streamed by the L1 kernel and the
    /// cosine fallback
    deq: Vec<f32>,
    /// sign plane (1-bit precision; empty otherwise)
    words: Vec<u64>,
}

/// Precision-specific backing store, one row per class.
#[derive(Clone, Debug)]
enum Store {
    /// sign planes, `words_per_row` u64 words per class (padding bits 0)
    B1 { words_per_row: usize, words: Vec<u64> },
    /// signed nibbles, two codes per byte (low nibble = even element)
    B4 { bytes_per_row: usize, bytes: Vec<u8> },
    B8 { codes: Vec<i8> },
    B16 { codes: Vec<i16> },
}

/// The packed class memory: every class HV quantized to `hv_bits` with a
/// per-class scale, stored at its storage precision.
#[derive(Clone, Debug)]
pub struct PackedClassHvs {
    pub n_classes: usize,
    pub d: usize,
    pub hv_bits: u32,
    /// per-class quantization scale
    scales: Vec<f32>,
    store: Store,
}

/// Sign-extend the 4-bit code at element `i` of a nibble row.
#[inline]
fn nibble_at(row: &[u8], i: usize) -> i32 {
    let b = row[i / 2];
    let n = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
    // the shift-left/shift-right pair below IS the sign extension, so:
    // lint:allow(unchecked-narrowing) same-width u8->i8 reinterpret, no bits lost
    (((n << 4) as i8) >> 4) as i32
}

/// Branch-free sign extension of a 4-bit code (the low nibble of `n`):
/// `(n ^ 8) - 8` maps 0..=15 onto -8..=7 with no narrowing cast at all —
/// the form the streamed inner loops use ([`nibble_at`] stays for the
/// random-access tails and `dequantize_row`).
#[inline]
fn sext4(n: u8) -> i32 {
    (n as i32 ^ 8) - 8
}

/// The 1-bit store never reaches the multi-bit kernels: `row_distance`
/// matches `Store::B1` first and routes every metric through the popcount
/// path, so the per-kernel `B1` arms are unreachable by construction.
/// Serving code sits one call above this module and must not panic in
/// release (the fsl-lint `panic-in-serving` policy boundary), so release
/// builds return a typed zero here; debug builds panic to catch a future
/// routing regression immediately.
#[cold]
#[inline(never)]
fn debug_unreachable_b1<T: Default>(kernel: &'static str) -> T {
    if cfg!(debug_assertions) {
        panic!("Store::B1 must route through the popcount path, not the {kernel} kernel");
    }
    T::default()
}

/// Pack the sign plane of a dequantized row (bit set ⇔ value >= 0.0 — the
/// same predicate `Distance::Hamming` applies, so ±0.0 rows agree too).
fn pack_signs(codes: &[i32], scale: f32, words_per_row: usize) -> Vec<u64> {
    let mut words = vec![0u64; words_per_row];
    for (i, &c) in codes.iter().enumerate() {
        if c as f32 * scale >= 0.0 {
            words[i / 64] |= 1u64 << (i % 64);
        }
    }
    words
}

impl PackedClassHvs {
    /// Quantize `n_classes` row-major f32 class HVs (`rows.len() == n*d`)
    /// into the packed store.
    pub fn from_rows(rows: &[f32], n_classes: usize, d: usize, hv_bits: u32) -> Self {
        assert_eq!(rows.len(), n_classes * d, "rows must be n_classes x d");
        assert!((1..=16).contains(&hv_bits), "HV precision is 1..=16 bits");
        let mut scales = Vec::with_capacity(n_classes);
        let quantized: Vec<Vec<i32>> = (0..n_classes)
            .map(|c| {
                let (codes, scale) = quant::quantize_codes(&rows[c * d..(c + 1) * d], hv_bits);
                scales.push(scale);
                codes
            })
            .collect();
        let store = match hv_bits {
            1 => {
                let wpr = d.div_ceil(64);
                let mut words = Vec::with_capacity(n_classes * wpr);
                for (codes, &scale) in quantized.iter().zip(&scales) {
                    words.extend(pack_signs(codes, scale, wpr));
                }
                Store::B1 { words_per_row: wpr, words }
            }
            2..=4 => {
                let bpr = d.div_ceil(2);
                let mut bytes = vec![0u8; n_classes * bpr];
                for (c, codes) in quantized.iter().enumerate() {
                    let row = &mut bytes[c * bpr..(c + 1) * bpr];
                    for (i, &code) in codes.iter().enumerate() {
                        debug_assert!((-8..=7).contains(&code), "4-bit code out of range");
                        let nib = (code as u8) & 0x0F;
                        row[i / 2] |= if i % 2 == 0 { nib } else { nib << 4 };
                    }
                }
                Store::B4 { bytes_per_row: bpr, bytes }
            }
            5..=8 => Store::B8 {
                codes: quantized
                    .iter()
                    .flat_map(|r| {
                        r.iter().map(|&c| {
                            debug_assert!(i8::try_from(c).is_ok(), "8-bit code out of range");
                            c as i8
                        })
                    })
                    .collect(),
            },
            _ => Store::B16 {
                codes: quantized
                    .iter()
                    .flat_map(|r| {
                        r.iter().map(|&c| {
                            debug_assert!(i16::try_from(c).is_ok(), "16-bit code out of range");
                            c as i16
                        })
                    })
                    .collect(),
            },
        };
        PackedClassHvs { n_classes, d, hv_bits, scales, store }
    }

    /// Whether `metric` reads the query's dequantized f32 view (`deq`):
    /// only the multi-bit L1 kernel and the cosine fallback do — every
    /// popcount / integer-domain path works from the codes alone.
    fn metric_needs_deq(&self, metric: Distance) -> bool {
        metric == Distance::Cosine || (self.hv_bits > 1 && metric == Distance::L1)
    }

    /// Quantize a query once to the class-memory precision, usable with
    /// any metric (the dequantized view is always materialized).
    pub fn quantize_query(&self, q: &[f32]) -> PackedQuery {
        self.build_query(q, true)
    }

    /// Like [`PackedClassHvs::quantize_query`], but skips the O(d)
    /// dequantized f32 materialization when `metric` never reads it —
    /// the allocation-light form the hot popcount/integer paths use.
    pub fn quantize_query_for(&self, q: &[f32], metric: Distance) -> PackedQuery {
        self.build_query(q, self.metric_needs_deq(metric))
    }

    fn build_query(&self, q: &[f32], with_deq: bool) -> PackedQuery {
        assert_eq!(q.len(), self.d, "query dimension mismatch");
        let (codes, scale) = quant::quantize_codes(q, self.hv_bits);
        let deq: Vec<f32> = if with_deq {
            codes.iter().map(|&c| c as f32 * scale).collect()
        } else {
            Vec::new()
        };
        let words = if self.hv_bits == 1 {
            pack_signs(&codes, scale, self.d.div_ceil(64))
        } else {
            Vec::new()
        };
        let codes16 = if self.hv_bits == 1 {
            Vec::new()
        } else {
            codes
                .iter()
                .map(|&c| {
                    debug_assert!(i16::try_from(c).is_ok(), "query code exceeds i16");
                    c as i16
                })
                .collect()
        };
        PackedQuery { d: self.d, hv_bits: self.hv_bits, scale, codes: codes16, deq, words }
    }

    /// Distance from a packed query to every class row, on the immutable
    /// process-wide kernel lane ([`simd::active_lane`]).
    pub fn distances(&self, pq: &PackedQuery, metric: Distance) -> Vec<f64> {
        self.distances_in_lane(pq, metric, simd::active_lane())
    }

    /// Like [`PackedClassHvs::distances`], but on a caller-chosen kernel
    /// lane. The global dispatch is deliberately immutable (see
    /// `util::simd`), so the simd-vs-scalar benches and the lane
    /// bit-identity prop tests compare lanes through this entry point —
    /// both lanes keep every per-metric oracle contract in the module
    /// docs, and return bit-identical results to each other.
    pub fn distances_in_lane(&self, pq: &PackedQuery, metric: Distance, lane: Lane) -> Vec<f64> {
        assert_eq!(pq.d, self.d, "query dimension mismatch");
        assert_eq!(pq.hv_bits, self.hv_bits, "query quantized at a different precision");
        assert!(
            !self.metric_needs_deq(metric) || pq.deq.len() == self.d,
            "query was packed without the dequantized view {metric:?} reads — \
             use quantize_query or quantize_query_for({metric:?})"
        );
        (0..self.n_classes).map(|c| self.row_distance(c, pq, metric, lane)).collect()
    }

    fn row_distance(&self, c: usize, pq: &PackedQuery, metric: Distance, lane: Lane) -> f64 {
        let sc = self.scales[c];
        let sq = pq.scale;
        if let Store::B1 { words_per_row, words } = &self.store {
            let row = &words[c * words_per_row..(c + 1) * words_per_row];
            let mis = simd::xor_popcount(row, &pq.words, lane);
            let n_match = self.d as u64 - mis;
            return match metric {
                Distance::Hamming => mis as f64,
                // ±s_q vs ±s_c: matches differ by |s_q - s_c|, mismatches
                // by s_q + s_c (both rounded in f32 like the oracle's a-b)
                Distance::L1 => {
                    n_match as f64 * ((sq - sc).abs() as f64) + mis as f64 * ((sq + sc) as f64)
                }
                Distance::Dot => -((n_match as f64 - mis as f64) * ((sq * sc) as f64)),
                Distance::Cosine => metric.eval(&pq.deq, &self.dequantize_row(c)),
            };
        }
        match metric {
            Distance::L1 => self.row_l1(c, &pq.deq, sc, lane),
            Distance::Dot => {
                -(self.row_dot_codes(c, &pq.codes, lane) as f64 * (sq as f64) * (sc as f64))
            }
            Distance::Hamming => self.row_sign_mismatches(c, &pq.codes) as f64,
            Distance::Cosine => metric.eval(&pq.deq, &self.dequantize_row(c)),
        }
    }

    /// Multi-bit L1: stream the narrow codes, dequantize in-register, and
    /// accumulate through an [`L1Sink`] with exactly `distance::l1`'s
    /// 4-lane structure — bit-identical to the f32 oracle on both kernel
    /// lanes (the sinks themselves are lane-bit-identical; `util::simd`).
    fn row_l1(&self, c: usize, qd: &[f32], scale: f32, lane: Lane) -> f64 {
        match lane {
            Lane::Chunked => self.row_l1_in::<simd::L1Chunked>(c, qd, scale),
            Lane::Simd => self.row_l1_in::<simd::L1Simd>(c, qd, scale),
        }
    }

    fn row_l1_in<S: L1Sink>(&self, c: usize, qd: &[f32], scale: f32) -> f64 {
        /// Aligned groups of four into the sink, scalar tail onto the
        /// folded sum (the oracle adds its tail sequentially too).
        #[inline]
        fn l1_slice<S: L1Sink, T: Copy>(
            qd: &[f32],
            row: &[T],
            scale: f32,
            f: impl Fn(T) -> f32,
        ) -> f64 {
            let n4 = qd.len() / 4 * 4;
            let mut sink = S::default();
            let mut i = 0;
            while i < n4 {
                sink.push4(
                    [qd[i], qd[i + 1], qd[i + 2], qd[i + 3]],
                    [f(row[i]), f(row[i + 1]), f(row[i + 2]), f(row[i + 3])],
                    scale,
                );
                i += 4;
            }
            let mut s = sink.finish();
            for j in n4..qd.len() {
                s += (qd[j] - f(row[j]) * scale).abs() as f64;
            }
            s
        }
        let d = self.d;
        match &self.store {
            Store::B4 { bytes_per_row, bytes } => {
                // byte-pair streaming: each step decodes two bytes (four
                // nibbles) straight into the sink — no per-element
                // nibble_at call in the loop
                let row = &bytes[c * bytes_per_row..(c + 1) * bytes_per_row];
                let n4 = d / 4 * 4;
                let mut sink = S::default();
                let mut i = 0;
                while i < n4 {
                    let (b0, b1) = (row[i / 2], row[i / 2 + 1]);
                    sink.push4(
                        [qd[i], qd[i + 1], qd[i + 2], qd[i + 3]],
                        [
                            sext4(b0 & 0x0F) as f32,
                            sext4(b0 >> 4) as f32,
                            sext4(b1 & 0x0F) as f32,
                            sext4(b1 >> 4) as f32,
                        ],
                        scale,
                    );
                    i += 4;
                }
                let mut s = sink.finish();
                for j in n4..d {
                    s += (qd[j] - nibble_at(row, j) as f32 * scale).abs() as f64;
                }
                s
            }
            Store::B8 { codes } => {
                l1_slice::<S, i8>(qd, &codes[c * d..(c + 1) * d], scale, |v| v as f32)
            }
            Store::B16 { codes } => {
                l1_slice::<S, i16>(qd, &codes[c * d..(c + 1) * d], scale, |v| v as f32)
            }
            Store::B1 { .. } => debug_unreachable_b1::<f64>("L1"),
        }
    }

    /// Multi-bit dot: exact integer accumulation over the code domain
    /// (order-independent, so any lane returns the same bits).
    fn row_dot_codes(&self, c: usize, qc: &[i16], lane: Lane) -> i64 {
        let d = self.d;
        match &self.store {
            Store::B4 { bytes_per_row, bytes } => {
                // byte-pair streaming with independent accumulators; the
                // exact integer sum makes one form serve both lanes
                let row = &bytes[c * bytes_per_row..(c + 1) * bytes_per_row];
                let n4 = d / 4 * 4;
                let mut acc = [0i64; 4];
                let mut i = 0;
                while i < n4 {
                    let (b0, b1) = (row[i / 2], row[i / 2 + 1]);
                    acc[0] += qc[i] as i64 * sext4(b0 & 0x0F) as i64;
                    acc[1] += qc[i + 1] as i64 * sext4(b0 >> 4) as i64;
                    acc[2] += qc[i + 2] as i64 * sext4(b1 & 0x0F) as i64;
                    acc[3] += qc[i + 3] as i64 * sext4(b1 >> 4) as i64;
                    i += 4;
                }
                let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
                for j in n4..d {
                    s += qc[j] as i64 * nibble_at(row, j) as i64;
                }
                s
            }
            Store::B8 { codes } => simd::dot_codes_i8(qc, &codes[c * d..(c + 1) * d], lane),
            Store::B16 { codes } => simd::dot_codes_i16(qc, &codes[c * d..(c + 1) * d], lane),
            Store::B1 { .. } => debug_unreachable_b1::<i64>("dot"),
        }
    }

    /// Multi-bit Hamming: sign mismatches in the code domain (`code >= 0`
    /// ⇔ dequantized `>= 0.0`, since scales are non-negative) — exactly
    /// the oracle's count.
    fn row_sign_mismatches(&self, c: usize, qc: &[i16]) -> u64 {
        #[inline]
        fn count(qc: &[i16], code: impl Fn(usize) -> i32) -> u64 {
            qc.iter().enumerate().filter(|&(i, &q)| (q >= 0) != (code(i) >= 0)).count() as u64
        }
        let d = self.d;
        match &self.store {
            Store::B4 { bytes_per_row, bytes } => {
                // exact mismatch count over streamed byte pairs
                let row = &bytes[c * bytes_per_row..(c + 1) * bytes_per_row];
                let n4 = d / 4 * 4;
                let mut acc = [0u64; 4];
                let mut i = 0;
                while i < n4 {
                    let (b0, b1) = (row[i / 2], row[i / 2 + 1]);
                    let cs = [sext4(b0 & 0x0F), sext4(b0 >> 4), sext4(b1 & 0x0F), sext4(b1 >> 4)];
                    for l in 0..4 {
                        acc[l] += ((qc[i + l] >= 0) != (cs[l] >= 0)) as u64;
                    }
                    i += 4;
                }
                let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
                for j in n4..d {
                    s += ((qc[j] >= 0) != (nibble_at(row, j) >= 0)) as u64;
                }
                s
            }
            Store::B8 { codes } => {
                let row = &codes[c * d..(c + 1) * d];
                count(qc, |i| row[i] as i32)
            }
            Store::B16 { codes } => {
                let row = &codes[c * d..(c + 1) * d];
                count(qc, |i| row[i] as i32)
            }
            Store::B1 { .. } => debug_unreachable_b1::<u64>("hamming"),
        }
    }

    /// Dequantize one class row back to the f32 view the oracle sees.
    pub fn dequantize_row(&self, c: usize) -> Vec<f32> {
        let d = self.d;
        let scale = self.scales[c];
        match &self.store {
            Store::B1 { words_per_row, words } => {
                let row = &words[c * words_per_row..(c + 1) * words_per_row];
                (0..d)
                    .map(|i| {
                        if (row[i / 64] >> (i % 64)) & 1 == 1 {
                            scale
                        } else {
                            -scale
                        }
                    })
                    .collect()
            }
            Store::B4 { bytes_per_row, bytes } => {
                let row = &bytes[c * bytes_per_row..(c + 1) * bytes_per_row];
                (0..d).map(|i| nibble_at(row, i) as f32 * scale).collect()
            }
            Store::B8 { codes } => {
                codes[c * d..(c + 1) * d].iter().map(|&v| v as f32 * scale).collect()
            }
            Store::B16 { codes } => {
                codes[c * d..(c + 1) * d].iter().map(|&v| v as f32 * scale).collect()
            }
        }
    }

    /// Dequantize every class row (row-major n_classes x d) — the oracle
    /// view of the whole class memory.
    pub fn dequantize_all(&self) -> Vec<f32> {
        (0..self.n_classes).flat_map(|c| self.dequantize_row(c)).collect()
    }

    /// Logical storage per class HV — what the chip's class memory holds
    /// (the `sim::hdc_engine` cross-check ties `distance_tally` to this).
    pub fn storage_bits_per_class(&self) -> u64 {
        quant::storage_bits(self.d, self.hv_bits)
    }

    /// Bits actually allocated per class row, including sub-word padding
    /// (codes narrower than their container round up: 5..=7-bit codes cost
    /// i8, 9..=15-bit cost i16).
    pub fn allocated_bits_per_class(&self) -> u64 {
        match &self.store {
            Store::B1 { words_per_row, .. } => *words_per_row as u64 * 64,
            Store::B4 { bytes_per_row, .. } => *bytes_per_row as u64 * 8,
            Store::B8 { .. } => self.d as u64 * 8,
            Store::B16 { .. } => self.d as u64 * 16,
        }
    }

    /// 256-bit class-memory segments one query walks — 16 lanes per cycle
    /// over every class row, the schedule `sim::hdc_engine::distance_tally`
    /// charges cycles for.
    pub fn segments_per_query(&self) -> u64 {
        (self.d as u64).div_ceil(16) * self.n_classes as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    const METRICS: [Distance; 4] =
        [Distance::L1, Distance::Dot, Distance::Hamming, Distance::Cosine];

    fn rows(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        (0..n * d).map(|_| 3.0 * rng.gauss_f32()).collect()
    }

    /// Oracle: quantize both sides to f32 and evaluate the plain metric.
    fn oracle(rows: &[f32], n: usize, d: usize, bits: u32, q: &[f32], m: Distance) -> Vec<f64> {
        let (qd, _) = quant::quantize(q, bits);
        (0..n)
            .map(|c| {
                let (cd, _) = quant::quantize(&rows[c * d..(c + 1) * d], bits);
                m.eval(&qd, &cd)
            })
            .collect()
    }

    #[test]
    fn dequantize_reproduces_quantize() {
        let mut rng = Rng::new(1);
        for d in [37usize, 64, 130] {
            let r = rows(&mut rng, 3, d);
            for bits in [1u32, 2, 4, 6, 8, 12, 16] {
                let p = PackedClassHvs::from_rows(&r, 3, d, bits);
                for c in 0..3 {
                    let (want, _) = quant::quantize(&r[c * d..(c + 1) * d], bits);
                    let got = p.dequantize_row(c);
                    assert_eq!(got.len(), want.len());
                    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(a, b, "d={d} bits={bits} class {c} idx {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_distances_match_oracle_all_precisions_and_metrics() {
        let mut rng = Rng::new(2);
        for d in [37usize, 96] {
            let r = rows(&mut rng, 4, d);
            let q: Vec<f32> = (0..d).map(|_| 3.0 * rng.gauss_f32()).collect();
            for bits in [1u32, 4, 8, 16] {
                let p = PackedClassHvs::from_rows(&r, 4, d, bits);
                let pq = p.quantize_query(&q);
                for m in METRICS {
                    let got = p.distances(&pq, m);
                    let want = oracle(&r, 4, d, bits, &q, m);
                    for (c, (a, b)) in got.iter().zip(&want).enumerate() {
                        // magnitude-aware tolerance: dot/1-bit paths round
                        // the scale product once instead of per element
                        let mag = p
                            .dequantize_row(c)
                            .iter()
                            .zip(&pq.deq)
                            .map(|(x, y)| (x.abs() * y.abs()) as f64)
                            .sum::<f64>();
                        let tol = 1e-6 * (1.0 + b.abs() + mag);
                        assert!(
                            (a - b).abs() <= tol,
                            "d={d} bits={bits} {m:?} class {c}: packed {a} vs oracle {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn multi_bit_l1_and_hamming_are_bit_exact() {
        let mut rng = Rng::new(3);
        let d = 111; // odd: nibble tail + partial 4-lane tail
        let r = rows(&mut rng, 3, d);
        let q: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
        for bits in [4u32, 8, 16] {
            let p = PackedClassHvs::from_rows(&r, 3, d, bits);
            let pq = p.quantize_query(&q);
            assert_eq!(p.distances(&pq, Distance::L1), oracle(&r, 3, d, bits, &q, Distance::L1));
            assert_eq!(
                p.distances(&pq, Distance::Hamming),
                oracle(&r, 3, d, bits, &q, Distance::Hamming)
            );
        }
        // 1-bit Hamming is exact too (popcount == the oracle's sign count)
        let p = PackedClassHvs::from_rows(&r, 3, d, 1);
        let pq = p.quantize_query(&q);
        assert_eq!(
            p.distances(&pq, Distance::Hamming),
            oracle(&r, 3, d, 1, &q, Distance::Hamming)
        );
    }

    #[test]
    fn kernel_lanes_are_bit_identical() {
        use crate::util::simd::Lane;
        let mut rng = Rng::new(7);
        for d in [70usize, 111, 256] {
            let r = rows(&mut rng, 4, d);
            let q: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
            for bits in [1u32, 2, 4, 8, 16] {
                let p = PackedClassHvs::from_rows(&r, 4, d, bits);
                let pq = p.quantize_query(&q);
                for m in METRICS {
                    let chunked = p.distances_in_lane(&pq, m, Lane::Chunked);
                    let simd = p.distances_in_lane(&pq, m, Lane::Simd);
                    assert_eq!(chunked, simd, "d={d} bits={bits} {m:?}: lanes diverged");
                    assert_eq!(chunked, p.distances(&pq, m), "active lane inconsistent");
                }
            }
        }
    }

    #[test]
    fn one_bit_popcount_counts_padding_free() {
        // d not a multiple of 64: padding bits must never contribute
        let d = 70;
        let r: Vec<f32> = (0..2 * d).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let p = PackedClassHvs::from_rows(&r, 2, d, 1);
        let q: Vec<f32> = (0..d).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let pq = p.quantize_query(&q);
        let h = p.distances(&pq, Distance::Hamming);
        assert_eq!(h, vec![0.0, 0.0], "identical sign patterns: zero mismatches");
        let q_flipped: Vec<f32> = q.iter().map(|v| -v).collect();
        let hf = p.distances(&p.quantize_query(&q_flipped), Distance::Hamming);
        assert_eq!(hf, vec![d as f64, d as f64]);
    }

    #[test]
    fn zero_rows_and_queries_are_safe() {
        let d = 40;
        let r = vec![0.0f32; 2 * d];
        for bits in [1u32, 4, 8, 16] {
            let p = PackedClassHvs::from_rows(&r, 2, d, bits);
            let pq = p.quantize_query(&vec![0.0; d]);
            for m in METRICS {
                let ds = p.distances(&pq, m);
                assert!(ds.iter().all(|v| v.is_finite()), "bits={bits} {m:?}: {ds:?}");
            }
        }
    }

    #[test]
    fn storage_accounting_matches_precision() {
        let mut rng = Rng::new(4);
        let (n, d) = (5usize, 4096usize);
        let r = rows(&mut rng, n, d);
        for bits in [1u32, 4, 8, 16] {
            let p = PackedClassHvs::from_rows(&r, n, d, bits);
            assert_eq!(p.storage_bits_per_class(), d as u64 * bits as u64);
            // tight packing at the power-of-two precisions with d % 64 == 0
            assert_eq!(p.allocated_bits_per_class(), p.storage_bits_per_class());
            assert_eq!(p.segments_per_query(), (d as u64 / 16) * n as u64);
        }
        // in-between precisions round up to their container
        let p6 = PackedClassHvs::from_rows(&r, n, d, 6);
        assert_eq!(p6.storage_bits_per_class(), d as u64 * 6);
        assert_eq!(p6.allocated_bits_per_class(), d as u64 * 8);
    }

    #[test]
    fn metric_scoped_queries_skip_deq_but_still_agree() {
        let mut rng = Rng::new(5);
        let d = 90;
        let r = rows(&mut rng, 3, d);
        let q: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
        let cases = [
            (1u32, Distance::Hamming),
            (1, Distance::L1),
            (4, Distance::Hamming),
            (8, Distance::Dot),
        ];
        for (bits, m) in cases {
            let p = PackedClassHvs::from_rows(&r, 3, d, bits);
            let lean = p.quantize_query_for(&q, m);
            assert!(lean.deq.is_empty(), "bits={bits} {m:?}: integer path needs no deq");
            assert_eq!(p.distances(&lean, m), p.distances(&p.quantize_query(&q), m));
        }
        // metrics that read the f32 view keep it
        let p = PackedClassHvs::from_rows(&r, 3, d, 4);
        assert_eq!(p.quantize_query_for(&q, Distance::L1).deq.len(), d);
        assert_eq!(p.quantize_query_for(&q, Distance::Cosine).deq.len(), d);
    }

    #[test]
    #[should_panic(expected = "dequantized view")]
    fn deq_less_query_rejected_for_l1() {
        let p = PackedClassHvs::from_rows(&[1.0f32; 16], 1, 16, 4);
        let lean = p.quantize_query_for(&[0.5f32; 16], Distance::Hamming);
        p.distances(&lean, Distance::L1);
    }

    #[test]
    #[should_panic(expected = "different precision")]
    fn mismatched_query_precision_rejected() {
        let p = PackedClassHvs::from_rows(&[1.0f32; 16], 1, 16, 4);
        let p8 = PackedClassHvs::from_rows(&[1.0f32; 16], 1, 16, 8);
        let pq = p8.quantize_query(&[0.5f32; 16]);
        p.distances(&pq, Distance::L1);
    }
}
