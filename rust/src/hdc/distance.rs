//! Distance metrics for HDC inference (eq. 5) — the chip's distance
//! calculation module supports absolute-difference (L1) accumulation;
//! cosine / dot / hamming are provided for the baseline comparisons.

/// Supported similarity/distance functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distance {
    /// Manhattan distance — the chip's datapath (|q - C| accumulate).
    L1,
    /// Negative dot product (so smaller = more similar, like a distance).
    Dot,
    /// Cosine distance 1 - cos(q, C).
    Cosine,
    /// Hamming distance on sign bits — for 1-bit class HVs.
    Hamming,
}

impl Distance {
    /// Parse a metric name (CLI `--metric`, TOML `hdc.metric`).
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "l1" | "manhattan" => Ok(Distance::L1),
            "dot" => Ok(Distance::Dot),
            "cosine" => Ok(Distance::Cosine),
            "hamming" => Ok(Distance::Hamming),
            other => anyhow::bail!("unknown metric {other} (l1|dot|cosine|hamming)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Distance::L1 => "l1",
            Distance::Dot => "dot",
            Distance::Cosine => "cosine",
            Distance::Hamming => "hamming",
        }
    }

    pub fn eval(&self, q: &[f32], c: &[f32]) -> f64 {
        debug_assert_eq!(q.len(), c.len());
        match self {
            Distance::L1 => l1(q, c),
            Distance::Dot => -dot(q, c),
            Distance::Cosine => {
                let d = dot(q, c);
                let nq = dot(q, q).max(1e-30).sqrt();
                let nc = dot(c, c).max(1e-30).sqrt();
                1.0 - d / (nq * nc)
            }
            Distance::Hamming => q
                .iter()
                .zip(c)
                .filter(|(a, b)| (**a >= 0.0) != (**b >= 0.0))
                .count() as f64,
        }
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    // 4-lane unrolled accumulation: the compiler vectorizes this cleanly
    let mut acc = [0f64; 4];
    let n4 = a.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        acc[0] += (a[i] * b[i]) as f64;
        acc[1] += (a[i + 1] * b[i + 1]) as f64;
        acc[2] += (a[i + 2] * b[i + 2]) as f64;
        acc[3] += (a[i + 3] * b[i + 3]) as f64;
        i += 4;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in n4..a.len() {
        s += (a[j] * b[j]) as f64;
    }
    s
}

#[inline]
pub fn l1(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = [0f64; 4];
    let n4 = a.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        acc[0] += (a[i] - b[i]).abs() as f64;
        acc[1] += (a[i + 1] - b[i + 1]).abs() as f64;
        acc[2] += (a[i + 2] - b[i + 2]).abs() as f64;
        acc[3] += (a[i + 3] - b[i + 3]).abs() as f64;
        i += 4;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in n4..a.len() {
        s += (a[j] - b[j]).abs() as f64;
    }
    s
}

/// Shared NaN-robust selection core for [`argmin`]/[`argmax`]: NaN
/// candidates are skipped and a NaN incumbent always loses, so a NaN
/// value can never win (note `total_cmp` alone would not fix the
/// sign-bit-set NaN, which sorts *below* -inf). All-NaN input still
/// returns 0 (there is no better answer).
fn arg_best<T: Copy + Into<f64>>(vals: &[T], better: impl Fn(f64, f64) -> bool) -> usize {
    let mut best = 0;
    for (i, &v) in vals.iter().enumerate().skip(1) {
        let v: f64 = v.into();
        if v.is_nan() {
            continue;
        }
        let b: f64 = vals[best].into();
        if b.is_nan() || better(v, b) {
            best = i;
        }
    }
    best
}

/// Index of the smallest distance (ties -> lowest index). NaN-robust
/// (consistent with the PR 2 NaN-sort sweep): the old `d < dists[best]`
/// comparison was false for *every* candidate once `dists[0]` was NaN,
/// silently returning class 0. Generic over `f32` and `f64` (f32 -> f64
/// conversion is exact) so every distance/logit selection in the crate
/// shares this one NaN-robust implementation instead of re-rolling the
/// NaN-blind loop per element type.
pub fn argmin<T: Copy + Into<f64>>(dists: &[T]) -> usize {
    arg_best(dists, |a, b| a < b)
}

/// Index of the largest value (ties -> lowest index) — the similarity /
/// logit twin of [`argmin`], same NaN rules. Used by the baseline
/// classifiers, whose hand-rolled `l > logits[best]` loops silently
/// elected class 0 on a NaN logit at index 0.
pub fn argmax<T: Copy + Into<f64>>(vals: &[T]) -> usize {
    arg_best(vals, |a, b| a > b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_basics() {
        assert_eq!(l1(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert_eq!(l1(&[0.0, 0.0, 0.0, 0.0, 1.0], &[1.0, 0.0, 0.0, 0.0, 0.0]), 2.0);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| (x * y) as f64).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn cosine_range() {
        let q = [1.0f32, 0.0];
        assert!((Distance::Cosine.eval(&q, &[1.0, 0.0])).abs() < 1e-9);
        assert!((Distance::Cosine.eval(&q, &[-1.0, 0.0]) - 2.0).abs() < 1e-9);
        assert!((Distance::Cosine.eval(&q, &[0.0, 1.0]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn hamming_counts_sign_flips() {
        let q = [1.0f32, -1.0, 1.0, -1.0];
        let c = [1.0f32, 1.0, -1.0, -1.0];
        assert_eq!(Distance::Hamming.eval(&q, &c), 2.0);
    }

    #[test]
    fn argmin_ties_low_index() {
        assert_eq!(argmin(&[3.0, 1.0, 1.0, 5.0]), 1);
        assert_eq!(argmin(&[0.5]), 0);
    }

    #[test]
    fn argmin_is_nan_blind_no_more() {
        // regression: a NaN at index 0 made every `d < dists[best]`
        // comparison false, silently electing class 0
        assert_eq!(argmin(&[f64::NAN, 5.0, 3.0]), 2);
        assert_eq!(argmin(&[2.0, f64::NAN, 1.0]), 2);
        assert_eq!(argmin(&[-f64::NAN, 1.0]), 1, "sign-bit NaN must not win either");
        assert_eq!(argmin(&[f64::NAN, f64::NAN]), 0, "all-NaN falls back to 0");
        assert_eq!(argmin(&[f64::NAN, f64::INFINITY]), 1, "inf beats NaN");
    }

    #[test]
    fn argmin_is_generic_over_f32() {
        // the f32 instantiation shares the NaN-robust core, so the same
        // regression battery must hold element-type-for-element-type
        assert_eq!(argmin(&[3.0f32, 1.0, 1.0, 5.0]), 1);
        assert_eq!(argmin(&[f32::NAN, 5.0, 3.0]), 2);
        assert_eq!(argmin(&[2.0f32, f32::NAN, 1.0]), 2);
        assert_eq!(argmin(&[-f32::NAN, 1.0]), 1, "sign-bit NaN must not win either");
        assert_eq!(argmin(&[f32::NAN, f32::NAN]), 0, "all-NaN falls back to 0");
    }

    #[test]
    fn argmax_mirrors_argmin_nan_rules() {
        assert_eq!(argmax(&[3.0f32, 9.0, 9.0, 5.0]), 1, "ties -> lowest index");
        assert_eq!(argmax(&[f32::NAN, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[2.0f64, f64::NAN, 7.0]), 2);
        assert_eq!(argmax(&[f64::NAN, f64::NAN]), 0, "all-NaN falls back to 0");
        assert_eq!(argmax(&[f64::NAN, f64::NEG_INFINITY]), 1, "-inf beats NaN");
    }

    #[test]
    fn metric_names_round_trip() {
        for m in [Distance::L1, Distance::Dot, Distance::Cosine, Distance::Hamming] {
            assert_eq!(Distance::from_name(m.name()).unwrap(), m);
        }
        assert_eq!(Distance::from_name("L1").unwrap(), Distance::L1);
        assert_eq!(Distance::from_name("manhattan").unwrap(), Distance::L1);
        let err = Distance::from_name("euclid").unwrap_err().to_string();
        assert!(err.contains("euclid") && err.contains("hamming"), "{err}");
    }

    #[test]
    fn dot_distance_orders_like_similarity() {
        let q = [1.0f32, 2.0, 3.0];
        let near = [1.1f32, 2.0, 2.9];
        let far = [-1.0f32, 0.0, 1.0];
        assert!(Distance::Dot.eval(&q, &near) < Distance::Dot.eval(&q, &far));
    }
}
