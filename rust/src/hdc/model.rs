//! HDC few-shot model: single-pass training (eq. 4) + distance inference
//! (eq. 5), with the chip's class-memory precision options.
//!
//! Inference runs through the packed quantized class memory
//! ([`crate::hdc::packed::PackedClassHvs`], rebuilt lazily after training
//! touches a class HV): queries quantize once and compare in the integer
//! domain, exactly like the chip's distance module. The readable
//! dequantized-f32 evaluation stays available as
//! [`HdcModel::distances_oracle`] — the numerical oracle the packed
//! kernels are tested against (see `hdc/packed.rs` for the per-metric
//! exactness contract).

use super::distance::{argmin, Distance};
use super::packed::PackedClassHvs;
use super::quant;
use crate::util::parallel::shard_map;

/// A trained (or in-training) HDC classification model.
#[derive(Clone, Debug)]
pub struct HdcModel {
    pub d: usize,
    pub n_classes: usize,
    /// accumulated class HVs (eq. 4), row-major (n_classes x d)
    sums: Vec<f32>,
    /// shots accumulated per class
    pub counts: Vec<u32>,
    /// packed quantized view used for inference (rebuilt lazily)
    packed: Option<PackedClassHvs>,
    pub hv_bits: u32,
    pub metric: Distance,
}

impl HdcModel {
    pub fn new(n_classes: usize, d: usize) -> Self {
        HdcModel {
            d,
            n_classes,
            sums: vec![0.0; n_classes * d],
            counts: vec![0; n_classes],
            packed: None,
            hv_bits: 16,
            metric: Distance::L1,
        }
    }

    pub fn with_precision(mut self, bits: u32) -> Self {
        self.hv_bits = bits;
        self.packed = None;
        self
    }

    pub fn with_metric(mut self, metric: Distance) -> Self {
        // the packed store is metric-independent — no invalidation needed
        self.metric = metric;
        self
    }

    /// Single-pass training: bundle one encoded shot into its class HV.
    pub fn train_shot(&mut self, class: usize, hv: &[f32]) {
        assert!(class < self.n_classes, "class {class} out of range");
        assert_eq!(hv.len(), self.d);
        let row = &mut self.sums[class * self.d..(class + 1) * self.d];
        for (a, b) in row.iter_mut().zip(hv) {
            *a += b;
        }
        self.counts[class] += 1;
        self.packed = None;
    }

    /// Batched single-pass training (Fig. 12): bundle all k same-class
    /// shot HVs in one sweep. Accumulation is row-major — shot by shot
    /// into the class row, the same order `train_shot` uses — so the
    /// result is **bit-identical** to k sequential `train_shot` calls
    /// (the old column-major loop strode across every shot HV per element
    /// and only matched within tolerance). Accepts `&[Vec<f32>]` or
    /// borrowed `&[&[f32]]` rows, so callers never have to clone HVs.
    pub fn train_batch<H: AsRef<[f32]>>(&mut self, class: usize, hvs: &[H]) {
        assert!(class < self.n_classes, "class {class} out of range");
        if hvs.is_empty() {
            return;
        }
        for hv in hvs {
            assert_eq!(hv.as_ref().len(), self.d);
        }
        let row = &mut self.sums[class * self.d..(class + 1) * self.d];
        for hv in hvs {
            for (a, b) in row.iter_mut().zip(hv.as_ref()) {
                *a += b;
            }
        }
        self.counts[class] += hvs.len() as u32;
        self.packed = None;
    }

    /// Class HVs normalized by shot count (centroid form), row-major.
    fn normalized_rows(&self) -> Vec<f32> {
        let mut rows = Vec::with_capacity(self.n_classes * self.d);
        for c in 0..self.n_classes {
            let cnt = self.counts[c].max(1) as f32;
            rows.extend(self.sums[c * self.d..(c + 1) * self.d].iter().map(|v| v / cnt));
        }
        rows
    }

    /// The packed quantized class memory (rebuilt lazily after training).
    pub fn packed(&mut self) -> &PackedClassHvs {
        if self.packed.is_none() {
            self.packed = Some(PackedClassHvs::from_rows(
                &self.normalized_rows(),
                self.n_classes,
                self.d,
                self.hv_bits,
            ));
        }
        self.packed.as_ref().unwrap()
    }

    /// Raw (unquantized, unnormalized) class HV — e.g. for export.
    pub fn raw_class_hv(&self, class: usize) -> &[f32] {
        &self.sums[class * self.d..(class + 1) * self.d]
    }

    /// The dequantized f32 view of the packed class memory, row-major —
    /// what the pre-packed implementation materialized on every rebuild.
    /// Benches time the plain metric over this as the fair f32 baseline;
    /// tests use it for magnitude-aware tolerances.
    pub fn dequantized_class_hvs(&mut self) -> Vec<f32> {
        self.packed().dequantize_all()
    }

    /// Distance from a query HV to every class HV, through the packed
    /// integer datapath (the query is quantized once to `hv_bits`).
    pub fn distances(&mut self, q: &[f32]) -> Vec<f64> {
        assert_eq!(q.len(), self.d);
        let metric = self.metric;
        let packed = self.packed();
        packed.distances(&packed.quantize_query_for(q, metric), metric)
    }

    /// The readable reference: quantize the query and every class HV to
    /// the dequantized f32 representation and evaluate the plain metric.
    /// This is the numerical oracle for the packed datapath (multi-bit L1
    /// and all Hamming distances match it bit-for-bit; dot and the 1-bit
    /// popcount formulas within f32-association tolerance).
    pub fn distances_oracle(&self, q: &[f32]) -> Vec<f64> {
        assert_eq!(q.len(), self.d);
        let (qd, _) = quant::quantize(q, self.hv_bits);
        let rows = self.normalized_rows();
        let d = self.d;
        (0..self.n_classes)
            .map(|c| {
                let (cd, _) = quant::quantize(&rows[c * d..(c + 1) * d], self.hv_bits);
                self.metric.eval(&qd, &cd)
            })
            .collect()
    }

    /// Batched [`HdcModel::distances`], sharded over `shards` scoped
    /// worker threads (`util::parallel::shard_map`). The packed view is
    /// built once, then borrowed by every shard; output is bit-identical
    /// to the serial loop for any shard count (DESIGN.md §Threading
    /// model).
    pub fn distances_batch(&mut self, queries: &[Vec<f32>], shards: usize) -> Vec<Vec<f64>> {
        let metric = self.metric;
        let packed = self.packed();
        // dimension mismatches panic inside quantize_query, like distances()
        shard_map(queries, shards, |q| {
            Ok(packed.distances(&packed.quantize_query_for(q, metric), metric))
        })
        .expect("packed distances are infallible")
    }

    /// Predict the class of a query HV.
    pub fn predict(&mut self, q: &[f32]) -> usize {
        argmin(&self.distances(q))
    }

    /// Batched [`HdcModel::predict`] over the sharded distance path —
    /// bit-identical to serial for any shard count.
    pub fn predict_batch(&mut self, queries: &[Vec<f32>], shards: usize) -> Vec<usize> {
        self.distances_batch(queries, shards).iter().map(|d| argmin(d)).collect()
    }

    /// True when every class has at least one shot.
    pub fn is_trained(&self) -> bool {
        self.counts.iter().all(|&c| c > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn cluster_hv(rng: &mut Rng, proto: &[f32], noise: f32) -> Vec<f32> {
        proto.iter().map(|&p| p + noise * rng.gauss_f32()).collect()
    }

    #[test]
    fn recovers_well_separated_classes() {
        let d = 512;
        let mut rng = Rng::new(1);
        let protos: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..d).map(|_| 3.0 * rng.gauss_f32()).collect())
            .collect();
        let mut m = HdcModel::new(4, d);
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..5 {
                m.train_shot(c, &cluster_hv(&mut rng, p, 0.5));
            }
        }
        assert!(m.is_trained());
        for (c, p) in protos.iter().enumerate() {
            let q = cluster_hv(&mut rng, p, 0.5);
            assert_eq!(m.predict(&q), c);
        }
    }

    #[test]
    fn batch_is_bit_identical_to_sequential() {
        let d = 64;
        let mut rng = Rng::new(2);
        let hvs: Vec<Vec<f32>> =
            (0..5).map(|_| (0..d).map(|_| rng.gauss_f32()).collect()).collect();
        let mut seq = HdcModel::new(2, d);
        for hv in &hvs {
            seq.train_shot(0, hv);
        }
        let mut bat = HdcModel::new(2, d);
        bat.train_batch(0, &hvs);
        // row-major accumulation adds shots in the same order train_shot
        // does, so the sums are bit-identical, not merely close
        assert_eq!(seq.raw_class_hv(0), bat.raw_class_hv(0));
        assert_eq!(seq.counts, bat.counts);
        // borrowed-slice batches take the same path
        let views: Vec<&[f32]> = hvs.iter().map(|h| h.as_slice()).collect();
        let mut bor = HdcModel::new(2, d);
        bor.train_batch(0, &views);
        assert_eq!(seq.raw_class_hv(0), bor.raw_class_hv(0));
    }

    #[test]
    fn packed_distances_match_oracle() {
        let d = 96;
        let mut rng = Rng::new(7);
        let mut m = HdcModel::new(3, d);
        for c in 0..3 {
            for _ in 0..3 {
                let hv: Vec<f32> = (0..d).map(|_| 2.0 * rng.gauss_f32()).collect();
                m.train_shot(c, &hv);
            }
        }
        let q: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
        for bits in [1u32, 4, 8, 16] {
            for metric in [Distance::L1, Distance::Dot, Distance::Hamming] {
                m = m.with_precision(bits).with_metric(metric);
                let got = m.distances(&q);
                let want = m.distances_oracle(&q);
                for (c, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                        "bits={bits} {metric:?} class {c}: {a} vs {b}"
                    );
                }
                assert_eq!(argmin(&got), argmin(&want), "bits={bits} {metric:?}");
            }
        }
    }

    #[test]
    fn distances_batch_bit_identical_to_serial() {
        let d = 80;
        let mut rng = Rng::new(8);
        let mut m = HdcModel::new(4, d).with_precision(4);
        for c in 0..4 {
            let hv: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
            m.train_shot(c, &hv);
        }
        let queries: Vec<Vec<f32>> =
            (0..9).map(|_| (0..d).map(|_| rng.gauss_f32()).collect()).collect();
        let serial = m.distances_batch(&queries, 1);
        let serial_preds = m.predict_batch(&queries, 1);
        for shards in [2usize, 3, 7] {
            assert_eq!(m.distances_batch(&queries, shards), serial, "shards={shards}");
            assert_eq!(m.predict_batch(&queries, shards), serial_preds, "shards={shards}");
        }
        // the serial batch agrees with the one-query path
        for (q, want) in queries.iter().zip(&serial) {
            assert_eq!(&m.distances(q), want);
        }
    }

    #[test]
    fn quantization_preserves_separable_predictions() {
        let d = 1024;
        let mut rng = Rng::new(3);
        let protos: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..d).map(|_| 2.0 * rng.gauss_f32()).collect())
            .collect();
        for bits in [1u32, 4, 8, 16] {
            let mut m = HdcModel::new(3, d).with_precision(bits);
            for (c, p) in protos.iter().enumerate() {
                for _ in 0..5 {
                    m.train_shot(c, &cluster_hv(&mut rng, p, 0.3));
                }
            }
            let mut correct = 0;
            for (c, p) in protos.iter().enumerate() {
                if m.predict(&cluster_hv(&mut rng, p, 0.3)) == c {
                    correct += 1;
                }
            }
            assert_eq!(correct, 3, "bits={bits}");
        }
    }

    #[test]
    fn hamming_metric_classifies_binarized_classes() {
        let d = 512;
        let mut rng = Rng::new(9);
        let protos: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..d).map(|_| 2.0 * rng.gauss_f32()).collect())
            .collect();
        let mut m = HdcModel::new(3, d).with_precision(1).with_metric(Distance::Hamming);
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..5 {
                m.train_shot(c, &cluster_hv(&mut rng, p, 0.3));
            }
        }
        for (c, p) in protos.iter().enumerate() {
            assert_eq!(m.predict(&cluster_hv(&mut rng, p, 0.3)), c);
        }
    }

    #[test]
    fn untrained_class_detected() {
        let mut m = HdcModel::new(3, 16);
        m.train_shot(0, &vec![1.0; 16]);
        assert!(!m.is_trained());
    }

    #[test]
    fn count_normalization_balances_shot_imbalance() {
        // class 0 has 10 shots, class 1 has 1 — normalization keeps the
        // decision boundary near the middle instead of favoring class 0
        let d = 256;
        let mut rng = Rng::new(4);
        let p0: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
        let p1: Vec<f32> = p0.iter().map(|v| -v).collect();
        let mut m = HdcModel::new(2, d);
        for _ in 0..10 {
            m.train_shot(0, &cluster_hv(&mut rng, &p0, 0.2));
        }
        m.train_shot(1, &cluster_hv(&mut rng, &p1, 0.2));
        assert_eq!(m.predict(&p1), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn class_bounds_checked() {
        let mut m = HdcModel::new(2, 8);
        m.train_shot(5, &vec![0.0; 8]);
    }
}
