//! HDC few-shot model: single-pass training (eq. 4) + distance inference
//! (eq. 5), with the chip's class-memory precision options.

use super::distance::{argmin, Distance};
use super::quant;

/// A trained (or in-training) HDC classification model.
#[derive(Clone, Debug)]
pub struct HdcModel {
    pub d: usize,
    pub n_classes: usize,
    /// accumulated class HVs (eq. 4), row-major (n_classes x d)
    sums: Vec<f32>,
    /// shots accumulated per class
    pub counts: Vec<u32>,
    /// quantized view used for inference (rebuilt lazily)
    quantized: Option<Vec<f32>>,
    pub hv_bits: u32,
    pub metric: Distance,
}

impl HdcModel {
    pub fn new(n_classes: usize, d: usize) -> Self {
        HdcModel {
            d,
            n_classes,
            sums: vec![0.0; n_classes * d],
            counts: vec![0; n_classes],
            quantized: None,
            hv_bits: 16,
            metric: Distance::L1,
        }
    }

    pub fn with_precision(mut self, bits: u32) -> Self {
        self.hv_bits = bits;
        self.quantized = None;
        self
    }

    pub fn with_metric(mut self, metric: Distance) -> Self {
        self.metric = metric;
        self
    }

    /// Single-pass training: bundle one encoded shot into its class HV.
    pub fn train_shot(&mut self, class: usize, hv: &[f32]) {
        assert!(class < self.n_classes, "class {class} out of range");
        assert_eq!(hv.len(), self.d);
        let row = &mut self.sums[class * self.d..(class + 1) * self.d];
        for (a, b) in row.iter_mut().zip(hv) {
            *a += b;
        }
        self.counts[class] += 1;
        self.quantized = None;
    }

    /// Batched single-pass training (Fig. 12): aggregate all k same-class
    /// shot HVs, then add once — identical math, one memory sweep.
    pub fn train_batch(&mut self, class: usize, hvs: &[Vec<f32>]) {
        assert!(class < self.n_classes);
        if hvs.is_empty() {
            return;
        }
        let row = &mut self.sums[class * self.d..(class + 1) * self.d];
        for hv in hvs {
            assert_eq!(hv.len(), self.d);
        }
        for i in 0..self.d {
            let mut s = 0f32;
            for hv in hvs {
                s += hv[i];
            }
            row[i] += s;
        }
        self.counts[class] += hvs.len() as u32;
        self.quantized = None;
    }

    /// Class HVs normalized by shot count (centroid form) and quantized to
    /// the configured class-memory precision.
    fn class_hvs(&mut self) -> &[f32] {
        if self.quantized.is_none() {
            let mut q = Vec::with_capacity(self.n_classes * self.d);
            for c in 0..self.n_classes {
                let cnt = self.counts[c].max(1) as f32;
                let row: Vec<f32> = self.sums[c * self.d..(c + 1) * self.d]
                    .iter()
                    .map(|v| v / cnt)
                    .collect();
                let (qr, _) = quant::quantize(&row, self.hv_bits);
                q.extend(qr);
            }
            self.quantized = Some(q);
        }
        self.quantized.as_ref().unwrap()
    }

    /// Raw (unquantized, unnormalized) class HV — e.g. for export.
    pub fn raw_class_hv(&self, class: usize) -> &[f32] {
        &self.sums[class * self.d..(class + 1) * self.d]
    }

    /// Distance from a query HV to every class HV.
    pub fn distances(&mut self, q: &[f32]) -> Vec<f64> {
        assert_eq!(q.len(), self.d);
        let d = self.d;
        let metric = self.metric;
        let n_classes = self.n_classes;
        let hvs = self.class_hvs();
        (0..n_classes)
            .map(|c| metric.eval(q, &hvs[c * d..(c + 1) * d]))
            .collect()
    }

    /// Predict the class of a query HV.
    pub fn predict(&mut self, q: &[f32]) -> usize {
        argmin(&self.distances(q))
    }

    /// True when every class has at least one shot.
    pub fn is_trained(&self) -> bool {
        self.counts.iter().all(|&c| c > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn cluster_hv(rng: &mut Rng, proto: &[f32], noise: f32) -> Vec<f32> {
        proto.iter().map(|&p| p + noise * rng.gauss_f32()).collect()
    }

    #[test]
    fn recovers_well_separated_classes() {
        let d = 512;
        let mut rng = Rng::new(1);
        let protos: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..d).map(|_| 3.0 * rng.gauss_f32()).collect())
            .collect();
        let mut m = HdcModel::new(4, d);
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..5 {
                m.train_shot(c, &cluster_hv(&mut rng, p, 0.5));
            }
        }
        assert!(m.is_trained());
        for (c, p) in protos.iter().enumerate() {
            let q = cluster_hv(&mut rng, p, 0.5);
            assert_eq!(m.predict(&q), c);
        }
    }

    #[test]
    fn batch_equals_sequential() {
        let d = 64;
        let mut rng = Rng::new(2);
        let hvs: Vec<Vec<f32>> =
            (0..5).map(|_| (0..d).map(|_| rng.gauss_f32()).collect()).collect();
        let mut seq = HdcModel::new(2, d);
        for hv in &hvs {
            seq.train_shot(0, hv);
        }
        let mut bat = HdcModel::new(2, d);
        bat.train_batch(0, &hvs);
        for i in 0..d {
            assert!((seq.raw_class_hv(0)[i] - bat.raw_class_hv(0)[i]).abs() < 1e-4);
        }
        assert_eq!(seq.counts, bat.counts);
    }

    #[test]
    fn quantization_preserves_separable_predictions() {
        let d = 1024;
        let mut rng = Rng::new(3);
        let protos: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..d).map(|_| 2.0 * rng.gauss_f32()).collect())
            .collect();
        for bits in [1u32, 4, 8, 16] {
            let mut m = HdcModel::new(3, d).with_precision(bits);
            for (c, p) in protos.iter().enumerate() {
                for _ in 0..5 {
                    m.train_shot(c, &cluster_hv(&mut rng, p, 0.3));
                }
            }
            let mut correct = 0;
            for (c, p) in protos.iter().enumerate() {
                if m.predict(&cluster_hv(&mut rng, p, 0.3)) == c {
                    correct += 1;
                }
            }
            assert_eq!(correct, 3, "bits={bits}");
        }
    }

    #[test]
    fn untrained_class_detected() {
        let mut m = HdcModel::new(3, 16);
        m.train_shot(0, &vec![1.0; 16]);
        assert!(!m.is_trained());
    }

    #[test]
    fn count_normalization_balances_shot_imbalance() {
        // class 0 has 10 shots, class 1 has 1 — normalization keeps the
        // decision boundary near the middle instead of favoring class 0
        let d = 256;
        let mut rng = Rng::new(4);
        let p0: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
        let p1: Vec<f32> = p0.iter().map(|v| -v).collect();
        let mut m = HdcModel::new(2, d);
        for _ in 0..10 {
            m.train_shot(0, &cluster_hv(&mut rng, &p0, 0.2));
        }
        m.train_shot(1, &cluster_hv(&mut rng, &p1, 0.2));
        assert_eq!(m.predict(&p1), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn class_bounds_checked() {
        let mut m = HdcModel::new(2, 8);
        m.train_shot(5, &vec![0.0; 8]);
    }
}
