//! Hyperdimensional-computing FSL classifier (Sections II-B, III-B, IV-B).
//!
//! Native mirror of the L1 kernels: the cRP encoder here is bit-compatible
//! with `python/compile/kernels/crp_encoder.py` (same LFSR stream, same
//! block schedule), so class HVs trained natively are interchangeable with
//! HVs produced by the PJRT artifacts.

pub mod class_mem;
pub mod crp;
pub mod distance;
pub mod lfsr;
pub mod model;
pub mod packed;
pub mod quant;

pub use crp::CrpEncoder;
pub use distance::Distance;
pub use model::HdcModel;
pub use packed::PackedClassHvs;
