//! Class-memory manager: allocation of class HVs in the chip's 256 KB,
//! 16-bank class memory (Section IV-B3/V-A).
//!
//! The memory holds, per FE branch, one class HV per session class at the
//! configured precision; capacity is what limits how many ways a session
//! may have (32-way @ 4-bit with EE branches, 32 classes @ 16-bit without,
//! 128 @ 4-bit). Unused banks are gated off (Fig. 9) — the manager reports
//! the gating level for the energy model.

/// One allocation: a session's class HVs for all branches.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Allocation {
    pub session: u64,
    pub n_classes: usize,
    pub n_branches: usize,
    pub hv_bits: u32,
    pub d: usize,
}

impl Allocation {
    pub fn bits(&self) -> u64 {
        self.n_classes as u64 * self.n_branches as u64 * self.d as u64 * self.hv_bits as u64
    }
}

/// Tracks what lives in class memory.
#[derive(Clone, Debug)]
pub struct ClassMemoryManager {
    pub capacity_bits: u64,
    pub banks: usize,
    allocations: Vec<Allocation>,
}

impl ClassMemoryManager {
    /// The chip's memory: 256 KB in 16 banks.
    pub fn paper() -> Self {
        ClassMemoryManager::new(256, 16)
    }

    pub fn new(kb: usize, banks: usize) -> Self {
        ClassMemoryManager {
            capacity_bits: kb as u64 * 1024 * 8,
            banks,
            allocations: Vec::new(),
        }
    }

    pub fn used_bits(&self) -> u64 {
        self.allocations.iter().map(|a| a.bits()).sum()
    }

    pub fn free_bits(&self) -> u64 {
        self.capacity_bits - self.used_bits()
    }

    /// Try to allocate; fails when the session would not fit on chip.
    pub fn allocate(&mut self, alloc: Allocation) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.allocations.iter().any(|a| a.session == alloc.session),
            "session {} already allocated",
            alloc.session
        );
        let need = alloc.bits();
        anyhow::ensure!(
            need <= self.free_bits(),
            "class memory exhausted: need {} KB, free {} KB (capacity {} KB) — \
             lower hv_bits or n_way",
            need / 8192,
            self.free_bits() / 8192,
            self.capacity_bits / 8192
        );
        self.allocations.push(alloc);
        Ok(())
    }

    pub fn release(&mut self, session: u64) -> bool {
        let before = self.allocations.len();
        self.allocations.retain(|a| a.session != session);
        self.allocations.len() != before
    }

    /// Banks that must stay powered for the current occupancy; the rest
    /// are gated (power saving counted by the energy model).
    pub fn active_banks(&self) -> usize {
        if self.capacity_bits == 0 {
            return 0;
        }
        let frac = self.used_bits() as f64 / self.capacity_bits as f64;
        ((frac * self.banks as f64).ceil() as usize).clamp(1, self.banks)
    }

    pub fn gated_banks(&self) -> usize {
        self.banks - self.active_banks()
    }

    /// Max ways a new session could still get at (d, bits, branches).
    pub fn max_ways(&self, d: usize, hv_bits: u32, n_branches: usize) -> usize {
        let per_class = d as u64 * hv_bits as u64 * n_branches as u64;
        (self.free_bits() / per_class) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc(session: u64, classes: usize, branches: usize, bits: u32) -> Allocation {
        Allocation { session, n_classes: classes, n_branches: branches, hv_bits: bits, d: 4096 }
    }

    #[test]
    fn paper_capacities() {
        let m = ClassMemoryManager::paper();
        // Section V-A: 32-way EE task at 4-bit fills the memory exactly
        assert_eq!(m.max_ways(4096, 4, 4), 32);
        // Section IV-B3: 32 classes @ 16-bit, 128 @ 4-bit (single branch)
        assert_eq!(m.max_ways(4096, 16, 1), 32);
        assert_eq!(m.max_ways(4096, 4, 1), 128);
    }

    #[test]
    fn allocate_and_release() {
        let mut m = ClassMemoryManager::paper();
        m.allocate(alloc(1, 10, 4, 4)).unwrap();
        assert!(m.used_bits() > 0);
        assert!(m.allocate(alloc(1, 5, 4, 4)).is_err(), "double alloc rejected");
        m.allocate(alloc(2, 10, 4, 4)).unwrap();
        assert!(m.release(1));
        assert!(!m.release(1));
        assert_eq!(m.used_bits(), alloc(2, 10, 4, 4).bits());
    }

    #[test]
    fn one_class_over_capacity_rejected() {
        // Section IV-B3: 128 classes @ 4-bit (D=4096, single branch) fill
        // the 256 KB exactly; 129 is one class over and must be rejected
        let mut m = ClassMemoryManager::paper();
        m.allocate(Allocation { session: 1, n_classes: 128, n_branches: 1, hv_bits: 4, d: 4096 })
            .unwrap();
        assert_eq!(m.free_bits(), 0, "128-way @ 4-bit is an exact fit");
        m.release(1);
        let e = m
            .allocate(Allocation { session: 2, n_classes: 129, n_branches: 1, hv_bits: 4, d: 4096 })
            .unwrap_err();
        assert!(e.to_string().contains("exhausted"), "{e}");
        // same boundary at 16-bit: 32 fits, 33 does not
        m.allocate(Allocation { session: 3, n_classes: 32, n_branches: 1, hv_bits: 16, d: 4096 })
            .unwrap();
        assert_eq!(m.free_bits(), 0);
        m.release(3);
        assert!(m
            .allocate(Allocation { session: 4, n_classes: 33, n_branches: 1, hv_bits: 16, d: 4096 })
            .is_err());
    }

    #[test]
    fn rejects_oversubscription() {
        let mut m = ClassMemoryManager::paper();
        m.allocate(alloc(1, 32, 4, 4)).unwrap(); // fills it
        assert_eq!(m.free_bits(), 0);
        let e = m.allocate(alloc(2, 1, 1, 1)).unwrap_err();
        assert!(e.to_string().contains("exhausted"));
    }

    #[test]
    fn bank_gating_tracks_occupancy() {
        let mut m = ClassMemoryManager::paper();
        assert_eq!(m.active_banks(), 1, "empty memory keeps one bank awake");
        m.allocate(alloc(1, 16, 4, 4)).unwrap(); // half full
        assert_eq!(m.active_banks(), 8);
        assert_eq!(m.gated_banks(), 8);
        m.allocate(alloc(2, 16, 4, 4)).unwrap(); // full
        assert_eq!(m.gated_banks(), 0);
    }

    #[test]
    fn sixteen_bit_sessions_cost_4x() {
        let m = ClassMemoryManager::paper();
        assert_eq!(m.max_ways(4096, 16, 4), 8, "16-bit EE sessions: only 8 ways fit");
    }
}
